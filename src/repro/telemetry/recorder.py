"""Flight-recorder telemetry: low-overhead spans, counters, and events.

The runtime-observability substrate for the whole stack (ISSUE 6): the FL
drivers (:mod:`repro.launch.fl_train`), the ground-segment router/engine
(:mod:`repro.groundseg`), the schedule optimizer
(:mod:`repro.constellation.optimizer`) and the fused exchange engine's
caches (:mod:`repro.core.fused`) all record here, and
:mod:`repro.telemetry.export` turns a recording into a Chrome-trace
(Perfetto-loadable) file plus a JSON metrics snapshot.

Contract (verified by ``tests/_telemetry_worker.py`` on 8 devices):

- **Counters are default-on and free of device traffic.** A counter bump
  is one Python dict update on the host; it never touches device values,
  never forces a transfer, and never changes what gets compiled — with
  telemetry disabled the compiled programs and their outputs are
  bit-identical to an uninstrumented build, and the driver loops issue
  ZERO additional host syncs.
- **Spans and events exist only while tracing is on.** Accurate per-round
  wall time needs a ``block_until_ready`` host sync, and per-payload
  lifecycle events are unbounded over a long run — both are opt-in via
  :func:`set_tracing` / ``record_scope(tracing=True)``. With tracing off,
  :meth:`Recorder.span` is a no-op context manager that records nothing
  and takes no timestamps.
- **Recordings are scoped, not global.** :func:`record_scope` pushes a
  fresh :class:`Recorder` for one benchmark/test/training run and pops it
  after, so counters cannot leak across runs (the bug the old bare
  ``fused._SPEC_CACHE_STATS`` module dict had).

The module is stdlib-only by design: :mod:`repro.core` imports it, so it
must sit below everything jax-flavored in the dependency order.
"""

from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Any, Dict, Iterator, List, Optional, Tuple

# Buffers are bounded so a default-on recorder in a long-running service
# cannot grow without limit; drops are themselves counted. Mirrors the
# ``dropped_log_max`` idiom from ``MultiWindowRouter``: the MOST RECENT
# entries are retained (drop-oldest), because in a long tracing run the
# tail — the windows around whatever went wrong — is the part you want.
MAX_SPANS = 100_000
MAX_EVENTS = 100_000


@dataclasses.dataclass(frozen=True)
class Span:
    """One timed interval (Chrome-trace ``"X"`` complete event)."""

    name: str
    cat: str
    t_start_us: float
    dur_us: float
    args: Dict[str, Any]
    tid: int = 0


@dataclasses.dataclass(frozen=True)
class Event:
    """One instant marker (Chrome-trace ``"i"`` instant event)."""

    name: str
    cat: str
    t_us: float
    args: Dict[str, Any]
    tid: int = 0


class Recorder:
    """A single flight recording: counters (always), spans/events (tracing).

    ``tracing``   — record spans/events and permit host-sync timing in the
                    instrumented drivers.
    ``reconcile`` — production-assert mode: drivers verify each newly
                    compiled round/window against the static collective
                    oracles via :mod:`repro.telemetry.reconcile` (costs one
                    HLO text parse per compile-cache miss; compiled
                    programs themselves are unchanged).
    """

    def __init__(
        self,
        tracing: bool = False,
        reconcile: bool = False,
        max_spans: Optional[int] = None,
        max_events: Optional[int] = None,
    ):
        self.tracing = bool(tracing)
        self.reconcile = bool(reconcile)
        self.counters: Dict[str, float] = {}
        # gauges (last-value-wins) and fixed-bucket histograms — written
        # through repro.telemetry.metrics, same default-on host-side
        # discipline as counters (hists values are metrics.Histogram;
        # typed Any here so this module stays import-root).
        self.gauges: Dict[str, float] = {}
        self.hists: Dict[str, Any] = {}
        self.spans: List[Span] = []
        self.events: List[Event] = []
        self.meta: Dict[str, Any] = {}
        self.max_spans = MAX_SPANS if max_spans is None else int(max_spans)
        self.max_events = MAX_EVENTS if max_events is None else int(max_events)
        self._t0_ns = time.perf_counter_ns()

    # -- clock ------------------------------------------------------------
    def now_us(self) -> float:
        """Microseconds since this recorder was created (monotonic)."""
        return (time.perf_counter_ns() - self._t0_ns) / 1e3

    # -- counters (default-on) --------------------------------------------
    def counter(self, name: str, inc: float = 1) -> None:
        self.counters[name] = self.counters.get(name, 0) + inc

    def set_counter(self, name: str, value: float) -> None:
        self.counters[name] = value

    def get_counter(self, name: str, default: float = 0) -> float:
        return self.counters.get(name, default)

    def pop_counters(self, prefix: str) -> Dict[str, float]:
        """Remove and return every counter under ``prefix`` (scope reset
        for one subsystem, e.g. ``fused.clear_spec_cache``)."""
        hit = [k for k in self.counters if k.startswith(prefix)]
        return {k: self.counters.pop(k) for k in hit}

    # -- events / spans (tracing only) ------------------------------------
    def event(self, name: str, cat: str = "event", tid: int = 0, **args) -> None:
        if not self.tracing:
            return
        self.events.append(Event(name, cat, self.now_us(), args, tid))
        if len(self.events) > self.max_events:
            drop = len(self.events) - self.max_events
            del self.events[:drop]
            self.counter("telemetry.dropped_events", drop)

    @contextlib.contextmanager
    def span(
        self, name: str, cat: str = "span", tid: int = 0, **args
    ) -> Iterator[Optional[Dict[str, Any]]]:
        """Time a block. Yields the (mutable) args dict so the body can
        attach results; yields ``None`` and records nothing when tracing
        is off."""
        if not self.tracing:
            yield None
            return
        t0 = self.now_us()
        try:
            yield args
        finally:
            self.spans.append(
                Span(name, cat, t0, self.now_us() - t0, dict(args), tid)
            )
            if len(self.spans) > self.max_spans:
                drop = len(self.spans) - self.max_spans
                del self.spans[:drop]
                self.counter("telemetry.dropped_spans", drop)

    # -- introspection ----------------------------------------------------
    def span_stats(self) -> Dict[str, Dict[str, float]]:
        """Aggregate spans by name: count / total / mean / max duration (ms)."""
        agg: Dict[str, Dict[str, float]] = {}
        for s in self.spans:
            a = agg.setdefault(
                s.name, {"count": 0, "total_ms": 0.0, "max_ms": 0.0}
            )
            a["count"] += 1
            a["total_ms"] += s.dur_us / 1e3
            a["max_ms"] = max(a["max_ms"], s.dur_us / 1e3)
        for a in agg.values():
            a["mean_ms"] = a["total_ms"] / max(a["count"], 1)
        return agg

    def clear(self) -> None:
        self.counters.clear()
        self.gauges.clear()
        self.hists.clear()
        self.spans.clear()
        self.events.clear()
        self.meta.clear()
        self._t0_ns = time.perf_counter_ns()


# ---------------------------------------------------------------------------
# The active recorder: a stack, so run scopes nest
# ---------------------------------------------------------------------------

_STACK: List[Recorder] = [Recorder()]


def get_recorder() -> Recorder:
    """The currently active recorder (innermost :func:`record_scope`, or
    the process-default one)."""
    return _STACK[-1]


def set_tracing(on: bool) -> None:
    """Enable/disable span+event recording on the ACTIVE recorder."""
    get_recorder().tracing = bool(on)


def set_reconcile(on: bool) -> None:
    """Enable/disable oracle reconciliation mode on the ACTIVE recorder."""
    get_recorder().reconcile = bool(on)


def tracing_enabled() -> bool:
    return get_recorder().tracing


@contextlib.contextmanager
def record_scope(
    tracing: Optional[bool] = None, reconcile: Optional[bool] = None
) -> Iterator[Recorder]:
    """Run one benchmark/test/training run against a FRESH recorder.

    Counters, spans, and events recorded inside the scope are isolated
    from (and invisible to) the enclosing scope; ``tracing``/``reconcile``
    default to the enclosing recorder's settings."""
    outer = get_recorder()
    rec = Recorder(
        tracing=outer.tracing if tracing is None else tracing,
        reconcile=outer.reconcile if reconcile is None else reconcile,
    )
    _STACK.append(rec)
    try:
        yield rec
    finally:
        _STACK.pop()


def counters_snapshot(prefix: str = "") -> Dict[str, float]:
    """Copy of the active recorder's counters (optionally filtered)."""
    return {
        k: v
        for k, v in get_recorder().counters.items()
        if k.startswith(prefix)
    }


__all__: Tuple[str, ...] = (
    "Event",
    "Recorder",
    "Span",
    "counters_snapshot",
    "get_recorder",
    "record_scope",
    "set_reconcile",
    "set_tracing",
    "tracing_enabled",
)
