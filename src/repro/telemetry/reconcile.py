"""Oracle reconciliation: check what was COMPILED against what was PLANNED.

The repo has strong static oracles — ``expected_collectives`` /
``expected_window_collectives`` for ground-segment programs and the M-per-
matching structure of the fused TDM engine — but until now nothing checked
a *running* system against them. This module turns the companion paper's
formal-verification idea ("observed execution traces conform to the
specified slot/exchange structure") into a production assert:

- :func:`compiled_collective_counts` parses a compiled module's HLO text
  (via :mod:`repro.launch.hlo_stats`, trip-count aware) into per-kind
  collective counts;
- :func:`check_compiled` compares them to a static expectation, records
  the outcome on the flight recorder (``reconcile.checked`` /
  ``reconcile.mismatched`` counters plus a trace event), and raises
  :class:`ReconciliationError` in strict mode;
- :func:`compile_and_check` is the driver hook: ahead-of-time compile a
  jitted round/window function, reconcile it, and hand back the compiled
  executable so the checked program is the one that runs. Drivers call it
  on every compile-cache MISS when the active recorder's ``reconcile``
  flag is set (:func:`repro.telemetry.recorder.set_reconcile`) — cache
  hits re-use already-reconciled executables, so steady state pays
  nothing.

:func:`expected_tdm_collectives` supplies the static oracle for one fused
TDM-FLA gossip round (M collective-permutes per dtype bucket, 2M for
int8/top-k payloads), mirroring what ``tests/_fused_worker.py`` proves
offline.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

from repro.telemetry.recorder import Recorder, get_recorder


class ReconciliationError(AssertionError):
    """A compiled program diverged from its static oracle."""


@dataclasses.dataclass(frozen=True)
class ReconcileReport:
    """Outcome of one compiled-vs-oracle comparison."""

    context: str
    expected: Dict[str, int]
    recorded: Dict[str, int]
    mismatches: Tuple[str, ...]

    @property
    def ok(self) -> bool:
        return not self.mismatches

    def describe(self) -> str:
        if self.ok:
            return f"[{self.context}] reconciled: {self.expected}"
        lines = [f"[{self.context}] collective counts diverged from oracle:"]
        for kind in self.mismatches:
            lines.append(
                f"  {kind}: expected {self.expected.get(kind, 0)}, "
                f"compiled {self.recorded.get(kind, 0)}"
            )
        return "\n".join(lines)


def compiled_collective_counts(hlo_text: str) -> Dict[str, int]:
    """Per-kind collective counts of a compiled module (trip-count aware)."""
    from repro.launch.hlo_stats import collective_stats

    stats = collective_stats(hlo_text)
    return {k: int(v) for k, v in stats.count_by_kind.items()}


def compare(
    expected: Dict[str, int],
    recorded: Dict[str, int],
    context: str = "",
) -> ReconcileReport:
    """Compare recorded counts against the oracle. Every kind the oracle
    names must match exactly; recorded kinds the oracle is silent about
    (e.g. an all-gather from parameter layout) are NOT failures — the
    oracle speaks only for the exchange structure it models."""
    mism = tuple(
        kind
        for kind, want in sorted(expected.items())
        if int(recorded.get(kind, 0)) != int(want)
    )
    return ReconcileReport(
        context=context,
        expected={k: int(v) for k, v in expected.items()},
        recorded=dict(recorded),
        mismatches=mism,
    )


def check_compiled(
    hlo_text: str,
    expected: Dict[str, int],
    *,
    context: str = "",
    recorder: Optional[Recorder] = None,
    strict: bool = True,
) -> ReconcileReport:
    """Reconcile one compiled module against its static oracle, recording
    the outcome on the flight recorder."""
    rec = recorder or get_recorder()
    report = compare(expected, compiled_collective_counts(hlo_text), context)
    rec.counter("reconcile.checked")
    if not report.ok:
        rec.counter("reconcile.mismatched")
    rec.event(
        "reconcile",
        cat="reconcile",
        context=context,
        ok=report.ok,
        expected=report.expected,
        recorded={k: report.recorded.get(k, 0) for k in report.expected},
    )
    if strict and not report.ok:
        raise ReconciliationError(report.describe())
    return report


def compile_and_check(
    fn,
    args: Tuple[Any, ...],
    expected: Optional[Dict[str, int]],
    *,
    context: str = "",
    recorder: Optional[Recorder] = None,
    strict: bool = True,
):
    """AOT-compile a jitted function, reconcile its HLO, return the
    compiled executable (which respects the jit's ``donate_argnums``).

    ``expected=None`` means no oracle covers this program — the compile
    still happens (the caller wanted the executable) but only a
    ``reconcile.skipped`` counter is recorded."""
    rec = recorder or get_recorder()
    compiled = fn.lower(*args).compile()
    if expected is None:
        rec.counter("reconcile.skipped")
    else:
        check_compiled(
            compiled.as_text(),
            expected,
            context=context,
            recorder=rec,
            strict=strict,
        )
    return compiled


def expected_tdm_collectives(
    rel,
    n_buckets: int,
    *,
    compression: str = "none",
) -> Dict[str, int]:
    """Static oracle for ONE fused TDM-FLA gossip round: the relation's
    matchings each cost one collective-permute per dtype bucket — two for
    int8 (payload + blockwise scales travel separately), ONE for top-k/CHOCO
    (values and block-local indices are packed into a single int32 payload
    by the fused ``topk_sparsify`` path) — independent of the model's leaf
    count (the PR 3 claim, HLO-verified offline in
    ``tests/_fused_worker.py``). The count is per BUCKET uniformly: every
    dtype bucket pays the same sidecar structure, which is what lets the
    oracle cover mixed-dtype compressed params."""
    from repro.core import tdm

    if len(rel) == 0:
        return {"collective-permute": 0}
    per = 2 if compression == "int8" else 1
    matchings = len(tdm.edge_coloring(rel))
    return {"collective-permute": matchings * per * int(n_buckets)}


def expected_hierarchical_collectives(
    intra_rel,
    inter_rel,
    n_buckets: int,
    *,
    compression: str = "none",
) -> Dict[str, int]:
    """Static oracle for one fused hierarchical (pod × data) round: the two
    levels gossip independently, so their per-level TDM counts add —
    ``(M_intra + M_inter) × per × n_buckets`` with ``per = 2`` for int8
    (:func:`repro.core.fused.fused_hierarchical_round`)."""
    if compression not in ("none", "int8"):
        raise ValueError(
            f"hierarchical gossip has no oracle for compression "
            f"{compression!r} (only 'none'/'int8' are lowered)"
        )
    intra = expected_tdm_collectives(
        intra_rel, n_buckets, compression=compression
    )
    inter = expected_tdm_collectives(
        inter_rel, n_buckets, compression=compression
    )
    return {
        "collective-permute": intra["collective-permute"]
        + inter["collective-permute"]
    }
