"""Mission reports: one run, one self-describing artifact.

ISSUE 9's last tentpole piece: gather everything the flight recorder and
the route auditor know about a run — counters, gauges, histogram
percentiles, per-stage wall-clock aggregates, the audit verdict — into a
single JSON document plus a human-readable markdown rendering. Benches
emit one per run (``--report PREFIX``) and nightly CI uploads them as
artifacts, so a regression hunt starts from one file instead of four
tools.

Stdlib-only (the telemetry packages never import jax): the report is
assembled from plain dicts, so it also serves as the stable machine-read
surface for downstream dashboards.
"""

from __future__ import annotations

import json
import pathlib
import time
from typing import Any, Dict, Optional, Tuple

from repro.telemetry import recorder as telemetry
from repro.telemetry.export import metrics_snapshot
from repro.telemetry.recorder import Recorder

SCHEMA_VERSION = 1


def mission_report(
    rec: Optional[Recorder] = None,
    *,
    audit: Optional[Any] = None,
    title: str = "mission report",
    extra: Optional[Dict[str, Any]] = None,
) -> Dict[str, Any]:
    """Assemble the JSON-able report document for one run.

    ``audit`` is an :class:`repro.telemetry.audit.AuditReport` (or anything
    with a ``summary()`` -> dict); ``extra`` merges caller context (bench
    config, row summaries) under its own key.
    """
    rec = rec or telemetry.get_recorder()
    snap = metrics_snapshot(rec)
    doc: Dict[str, Any] = {
        "schema_version": SCHEMA_VERSION,
        "title": title,
        "generated_unix_s": time.time(),
        "counters": snap["counters"],
        "gauges": snap["gauges"],
        "histograms": snap["histograms"],
        "stages": rec.span_stats(),
        "n_spans": snap["n_spans"],
        "n_events": snap["n_events"],
        "meta": snap["meta"],
    }
    if audit is not None:
        doc["audit"] = audit.summary() if hasattr(audit, "summary") else audit
    if extra:
        doc["extra"] = dict(extra)
    return doc


def _fmt(v: float) -> str:
    if v == int(v) and abs(v) < 1e15:
        return str(int(v))
    return f"{float(v):.6g}"


def render_markdown(doc: Dict[str, Any]) -> str:
    """Render a mission-report document as GitHub-flavored markdown."""
    lines = [f"# {doc.get('title', 'mission report')}", ""]
    audit = doc.get("audit")
    if audit is not None:
        verdict = "PASS" if audit.get("ok") else "FAIL"
        lines += [
            f"**Route-provenance audit: {verdict}** — "
            f"{audit.get('n_windows', 0)} windows, "
            f"{audit.get('n_payloads', 0)} payloads, "
            f"{audit.get('n_hops', 0)} hops, "
            f"{audit.get('n_violations', 0)} violation(s).",
            "",
        ]
        for v in audit.get("violations", []):
            lines.append(f"- {v}")
        if audit.get("violations"):
            lines.append("")
    stages = doc.get("stages") or {}
    if stages:
        lines += [
            "## Stage walls",
            "",
            "| stage | count | total ms | mean ms | max ms |",
            "| --- | ---: | ---: | ---: | ---: |",
        ]
        for name, s in sorted(
            stages.items(), key=lambda kv: -kv[1].get("total_ms", 0)
        ):
            lines.append(
                f"| `{name}` | {int(s.get('count', 0))} "
                f"| {s.get('total_ms', 0):.3f} | {s.get('mean_ms', 0):.3f} "
                f"| {s.get('max_ms', 0):.3f} |"
            )
        lines.append("")
    hists = doc.get("histograms") or {}
    if hists:
        lines += [
            "## Distributions",
            "",
            "| metric | count | mean | p50 | p90 | p99 | max |",
            "| --- | ---: | ---: | ---: | ---: | ---: | ---: |",
        ]
        for name, h in sorted(hists.items()):
            lines.append(
                f"| `{name}` | {int(h['count'])} | {_fmt(h['mean'])} "
                f"| {_fmt(h['p50'])} | {_fmt(h['p90'])} | {_fmt(h['p99'])} "
                f"| {_fmt(h['max'])} |"
            )
        lines.append("")
    gauges = doc.get("gauges") or {}
    if gauges:
        lines += ["## Gauges", "", "| gauge | value |", "| --- | ---: |"]
        for name, v in sorted(gauges.items()):
            lines.append(f"| `{name}` | {_fmt(v)} |")
        lines.append("")
    counters = doc.get("counters") or {}
    if counters:
        lines += ["## Counters", "", "| counter | value |", "| --- | ---: |"]
        for name, v in sorted(counters.items()):
            lines.append(f"| `{name}` | {_fmt(v)} |")
        lines.append("")
    extra = doc.get("extra") or {}
    if extra:
        lines += [
            "## Run context",
            "",
            "```json",
            json.dumps(extra, indent=2, sort_keys=True, default=str),
            "```",
            "",
        ]
    lines.append(
        f"_spans: {doc.get('n_spans', 0)}, events: {doc.get('n_events', 0)}, "
        f"schema v{doc.get('schema_version', SCHEMA_VERSION)}_"
    )
    return "\n".join(lines) + "\n"


def write_report(
    prefix: str,
    rec: Optional[Recorder] = None,
    *,
    audit: Optional[Any] = None,
    title: str = "mission report",
    extra: Optional[Dict[str, Any]] = None,
) -> Tuple[pathlib.Path, pathlib.Path]:
    """Write ``PREFIX.md`` + ``PREFIX.json`` and return both paths."""
    doc = mission_report(rec, audit=audit, title=title, extra=extra)
    base = pathlib.Path(prefix)
    base.parent.mkdir(parents=True, exist_ok=True)
    md = base.with_suffix(".md")
    js = base.with_suffix(".json")
    md.write_text(render_markdown(doc))
    js.write_text(json.dumps(doc, indent=2, sort_keys=True, default=str))
    return md, js


__all__ = (
    "SCHEMA_VERSION",
    "mission_report",
    "render_markdown",
    "write_report",
)
