"""Route-provenance auditing: prove the execution followed the plan.

The paper's validation criterion is that every node's executed exchanges
match the communication plan; the companion work "Formal Verification of a
Generic Algorithm for TDM Communication Over Inter Satellite Links"
(PAPERS.md) machine-checks exactly that plan-vs-execution property. This
module is the runtime twin (ISSUE 9): given the
:class:`~repro.groundseg.routing.WindowProgram` sequence a run planned —
and optionally the payload lifecycle events the flight recorder captured
while executing it — reconstruct every payload's hop-by-hop trail and
cross-check, per window:

- **conservation / misrouting** — replaying ``uplink.slot_sends`` from the
  window's initial loads must land exactly the payload sets
  ``uplink.delivered`` claims at each sink, strand nothing mid-route, and
  leave every undelivered payload parked at its own source (the
  delay-tolerant invariant the multi-window router relies on);
- **TDM legality** — per slot, uplink senders are unique (accumulate-and-
  forward out-degree <= 1) and downlink receivers have exactly one parent;
  with the window's slot relations supplied, every hop must ride an edge
  that physically exists in that slot;
- **capacity disjointness at** ``pipeline_depth=2`` — the lagged downlink
  flood may only use undirected edges the uplink relay left free, slot by
  slot;
- **age bookkeeping** — ``ages``/``delivered_ages``/``residual``/
  ``dropped`` must evolve across windows exactly as the queue discipline
  specifies (carried payloads age by one, drops exceed the horizon by
  construction, a source is never double-queued);
- **staleness weights** — the per-sink FedAvg denominators must equal
  ``1 + sum(decay ** age)`` over the delivered payloads, recomputed here
  with the same repeated-f32-multiply the aggregation engine uses;
- **lifecycle events** — the ``payload.queued/delivered/carried/dropped``
  instants a traced run emitted must match the plan payload-for-payload.

Violations come back as a structured :class:`AuditReport` (raise with
:meth:`AuditReport.raise_if_violations`); ``python -m repro.telemetry.audit
--ci-smoke`` runs the auditor over a small ground-segment plan as a CI
gate. Stdlib + numpy only — no jax — so auditing never perturbs the run
it is checking.
"""

from __future__ import annotations

import argparse
import dataclasses
from typing import Dict, FrozenSet, List, Optional, Sequence, Tuple

import numpy as np

from repro.telemetry import metrics
from repro.telemetry import recorder as telemetry
from repro.telemetry.recorder import Event, Recorder

# payload lifecycle event names (emitted by launch.fl_train's pipelined
# driver, cat="payload") -> the WindowProgram attribute they must mirror
_EVENT_KINDS = ("queued", "delivered", "carried", "dropped")

WEIGHT_ATOL = 1e-5


class AuditError(RuntimeError):
    """Raised by :meth:`AuditReport.raise_if_violations`."""


@dataclasses.dataclass(frozen=True)
class AuditViolation:
    """One way the execution (or the plan itself) broke its contract."""

    kind: str        # "misroute" | "fanout" | "phantom-hop" | "no-such-link"
    #                | "stranded" | "capacity-overlap" | "age" | "weights"
    #                | "events" | "double-queue"
    window: int
    detail: str
    payload: Optional[int] = None   # source satellite id, when applicable

    def __str__(self) -> str:
        who = f" payload={self.payload}" if self.payload is not None else ""
        return f"[{self.kind}] window {self.window}{who}: {self.detail}"


@dataclasses.dataclass(frozen=True)
class PayloadTrail:
    """One payload's reconstructed provenance within one window."""

    window: int
    source: int
    age: int
    sink: Optional[int]                 # None: carried into the next window
    hops: Tuple[Tuple[int, int, int], ...]   # (slot, src, dst)


@dataclasses.dataclass
class AuditReport:
    """The auditor's verdict over a window-program sequence."""

    n_windows: int = 0
    n_payloads: int = 0
    n_hops: int = 0
    n_delivered: int = 0
    n_dropped: int = 0
    events_checked: int = 0
    violations: List[AuditViolation] = dataclasses.field(default_factory=list)
    trails: Dict[Tuple[int, int], PayloadTrail] = dataclasses.field(
        default_factory=dict
    )   # (window, source) -> trail

    @property
    def ok(self) -> bool:
        return not self.violations

    def summary(self) -> Dict[str, object]:
        """JSON-able digest for mission reports / CI logs."""
        return {
            "ok": self.ok,
            "n_windows": self.n_windows,
            "n_payloads": self.n_payloads,
            "n_hops": self.n_hops,
            "n_delivered": self.n_delivered,
            "n_dropped": self.n_dropped,
            "events_checked": self.events_checked,
            "n_violations": len(self.violations),
            "violations": [str(v) for v in self.violations],
        }

    def raise_if_violations(self) -> "AuditReport":
        if self.violations:
            head = "; ".join(str(v) for v in self.violations[:5])
            more = len(self.violations) - 5
            raise AuditError(
                f"route-provenance audit failed with "
                f"{len(self.violations)} violation(s): {head}"
                + (f"; ... {more} more" if more > 0 else "")
            )
        return self


def expected_sink_weights(wp, decay: float) -> Dict[int, float]:
    """Per-sink FedAvg denominator ``1 + sum(decay ** age)`` over the
    delivered payloads — the same repeated-f32-multiply recurrence
    :func:`repro.groundseg.aggregation.staleness_sink_weights` applies, so
    a correct engine matches bit-for-bit (jax-free twin)."""
    out: Dict[int, float] = {}
    for k, srcs in wp.uplink.delivered.items():
        total = np.float32(1.0)
        for s in sorted(srcs):
            ws = np.float32(1.0)
            for _ in range(int(wp.delivered_ages.get(s, 0))):
                ws = np.float32(ws * np.float32(decay))
            total = np.float32(total + ws)
        out[int(k)] = float(total)
    return out


def _undirected(sends) -> List[Tuple[int, int]]:
    return [(min(s, d), max(s, d)) for s, d in sends]


def _replay_uplink(wp, slots, report: AuditReport) -> None:
    """Re-execute the uplink send plan and diff it against the outcome the
    program claims (delivered / residual / trail shape)."""
    w = wp.window
    sinks = wp.uplink.sinks
    carrying: Dict[int, set] = {
        s: {s} for s in wp.ages if s not in sinks
    }
    hops: Dict[int, List[Tuple[int, int, int]]] = {s: [] for s in carrying}
    delivered: Dict[int, set] = {k: set() for k in sinks}
    slot_edges: Optional[List[set]] = None
    if slots is not None:
        slot_edges = [set(_undirected(r.edge_list())) for r in slots]
    for t, sends in enumerate(wp.uplink.slot_sends):
        srcs = [s for s, _ in sends]
        if len(srcs) != len(set(srcs)):
            report.violations.append(AuditViolation(
                "fanout", w,
                f"slot {t}: uplink source sends twice in one slot: {sends}",
            ))
        # TDM slot sends are simultaneous: every sender ships the load it
        # held at slot START (a same-slot receive waits for the next slot),
        # so snapshot all outgoing loads before applying any deposit.
        outgoing: List[Tuple[int, int, set]] = []
        for s, d in sends:
            if slot_edges is not None:
                if t >= len(slot_edges) or (
                    (min(s, d), max(s, d)) not in slot_edges[t]
                ):
                    report.violations.append(AuditViolation(
                        "no-such-link", w,
                        f"slot {t}: hop {s}->{d} rides a link that does "
                        "not exist in that slot's relation",
                    ))
            load = set(carrying.get(s, ()))
            if not load:
                report.violations.append(AuditViolation(
                    "phantom-hop", w,
                    f"slot {t}: {s} sends to {d} but carries no payload",
                ))
                continue
            outgoing.append((s, d, load))
        for s, _d, _load in outgoing:
            carrying.pop(s, None)
        for s, d, load in outgoing:
            for p in load:
                hops.setdefault(p, []).append((t, s, d))
            if d in sinks:
                delivered[d] |= load
            else:
                carrying.setdefault(d, set()).update(load)

    claimed = {k: set(v) for k, v in wp.uplink.delivered.items()}
    for k in sorted(set(claimed) | set(delivered)):
        got, want = delivered.get(k, set()), claimed.get(k, set())
        if got != want:
            report.violations.append(AuditViolation(
                "misroute", w,
                f"sink {k}: replay delivers {sorted(got)} but the program "
                f"claims {sorted(want)}",
            ))
    leftovers = {p for load in carrying.values() for p in load}
    for holder, load in sorted(carrying.items()):
        for p in sorted(load):
            if holder != p:
                report.violations.append(AuditViolation(
                    "stranded", w,
                    f"payload {p} ends the window at node {holder}, not at "
                    "its own source (delay-tolerant invariant broken)",
                    payload=p,
                ))
    if leftovers != set(wp.residual):
        report.violations.append(AuditViolation(
            "misroute", w,
            f"residual mismatch: replay strands {sorted(leftovers)}, the "
            f"program claims {sorted(wp.residual)}",
        ))

    all_delivered = {p for load in delivered.values() for p in load}
    for s in sorted(wp.ages):
        sink = next((k for k, load in delivered.items() if s in load), None)
        trail = PayloadTrail(
            window=w,
            source=s,
            age=int(wp.ages[s]),
            sink=sink,
            hops=tuple(hops.get(s, ())),
        )
        report.trails[(w, s)] = trail
        report.n_hops += len(trail.hops)
        metrics.observe(
            "audit.hops_per_payload",
            len(trail.hops),
            buckets=metrics.COUNT_BUCKETS,
        )
    report.n_payloads += len(wp.ages)
    report.n_delivered += len(all_delivered)


def _check_downlink(wp, slots, report: AuditReport) -> None:
    """Downlink fan-in legality + disjoint-capacity at pipeline depth 2."""
    if wp.downlink is None:
        return
    w = wp.window
    up_edges = [set(_undirected(s)) for s in wp.uplink.slot_sends]
    for t, sends in enumerate(wp.downlink.slot_sends):
        dsts = [d for _, d in sends]
        if len(dsts) != len(set(dsts)):
            report.violations.append(AuditViolation(
                "fanout", w,
                f"slot {t}: downlink receiver has two parents: {sends}",
            ))
        if wp.lagged_downlink and t < len(up_edges):
            overlap = set(_undirected(sends)) & up_edges[t]
            if overlap:
                report.violations.append(AuditViolation(
                    "capacity-overlap", w,
                    f"slot {t}: downlink floods over uplink-occupied "
                    f"edges {sorted(overlap)} (depth-2 capacity must be "
                    "disjoint)",
                ))
        if slots is not None:
            edges = set(_undirected(slots[t].edge_list())) if t < len(
                slots
            ) else set()
            for s, d in sends:
                if (min(s, d), max(s, d)) not in edges:
                    report.violations.append(AuditViolation(
                        "no-such-link", w,
                        f"slot {t}: downlink hop {s}->{d} rides a link "
                        "that does not exist in that slot's relation",
                    ))


def _check_ledger(
    wp, pending_prev: Dict[int, int], report: AuditReport
) -> Dict[int, int]:
    """Age bookkeeping across window boundaries (the queue discipline)."""
    w = wp.window
    expected_aged = {s: a + 1 for s, a in pending_prev.items()}
    for s, a in sorted(wp.dropped.items()):
        want = expected_aged.get(s)
        if want is None or a != want:
            report.violations.append(AuditViolation(
                "age", w,
                f"dropped payload {s} at age {a}, but the ledger expected "
                f"{'nothing pending' if want is None else f'age {want}'}",
                payload=s,
            ))
    carried_expected = {
        s: a for s, a in expected_aged.items() if s not in wp.dropped
    }
    for s in sorted(wp.injected):
        if s in carried_expected:
            report.violations.append(AuditViolation(
                "double-queue", w,
                f"source {s} injected a fresh payload while one is still "
                f"queued at age {carried_expected[s]}",
                payload=s,
            ))
        if wp.ages.get(s, None) != 0:
            report.violations.append(AuditViolation(
                "age", w,
                f"fresh payload {s} has age {wp.ages.get(s)!r}, want 0",
                payload=s,
            ))
    for s, a in sorted(wp.ages.items()):
        if s in wp.injected:
            continue
        want = carried_expected.get(s)
        if want is None or a != want:
            report.violations.append(AuditViolation(
                "age", w,
                f"carried payload {s} shows age {a}, ledger expected "
                f"{'no queued payload' if want is None else f'age {want}'}",
                payload=s,
            ))
    for s, a in sorted(wp.delivered_ages.items()):
        if wp.ages.get(s) != a:
            report.violations.append(AuditViolation(
                "age", w,
                f"delivered_ages[{s}]={a} disagrees with ages[{s}]="
                f"{wp.ages.get(s)!r}",
                payload=s,
            ))
    report.n_dropped += len(wp.dropped)
    return dict(wp.residual)


def _check_weights(
    wp, decay: float, weights, report: AuditReport
) -> None:
    """The staleness denominators actually used must equal decay**age."""
    want = expected_sink_weights(wp, decay)
    if weights is None:
        return
    arr = np.asarray(weights, dtype=np.float32)
    for k, wv in sorted(want.items()):
        got = float(arr[k]) if k < arr.shape[0] else float("nan")
        if not np.isfinite(got) or abs(got - wv) > WEIGHT_ATOL:
            report.violations.append(AuditViolation(
                "weights", wp.window,
                f"sink {k}: staleness weight {got!r} != decay**age "
                f"expectation {wv!r} (decay={decay})",
            ))
    for v, got in enumerate(arr.tolist()):
        if v not in want and got not in (0.0,):
            report.violations.append(AuditViolation(
                "weights", wp.window,
                f"node {v}: nonzero weight {got!r} but no deliveries "
                "landed there",
            ))


def _check_events(
    programs, events: Sequence[Event], report: AuditReport
) -> None:
    """Executed lifecycle instants must mirror the plan payload-by-payload."""
    windows = {wp.window: wp for wp in programs}
    seen: Dict[Tuple[int, str], set] = {}
    for e in events:
        if e.cat != "payload":
            continue
        kind = e.name.split(".", 1)[-1]
        if kind not in _EVENT_KINDS:
            continue
        w = e.args.get("window")
        src = e.args.get("source")
        if w not in windows:
            report.violations.append(AuditViolation(
                "events", -1 if w is None else int(w),
                f"{e.name} for source {src} in window {w!r}, which is "
                "outside the audited program sequence",
                payload=src,
            ))
            continue
        seen.setdefault((int(w), kind), set()).add(
            (int(src), e.args.get("age"))
        )
        report.events_checked += 1
    for wp in programs:
        w = wp.window
        want = {
            "queued": {(s, None) for s in wp.injected},
            "delivered": {(s, a) for s, a in wp.delivered_ages.items()},
            "carried": {(s, a) for s, a in wp.residual.items()},
            "dropped": {(s, a) for s, a in wp.dropped.items()},
        }
        for kind, expect in want.items():
            got = seen.get((w, kind), set())
            if got != expect:
                extra = sorted(got - expect)
                missing = sorted(expect - got)
                report.violations.append(AuditViolation(
                    "events", w,
                    f"payload.{kind} events diverge from the plan: "
                    f"unexpected {extra}, missing {missing}",
                ))


def audit_window_programs(
    programs: Sequence,
    *,
    decay: float = 1.0,
    slots: Optional[Sequence] = None,
    weights: Optional[Sequence] = None,
    events: Optional[Sequence[Event]] = None,
    pending_start: Optional[Dict[int, int]] = None,
) -> AuditReport:
    """Audit a consecutive :class:`WindowProgram` sequence end to end.

    ``slots`` (optional) is the per-window slot-relation list the router
    planned over — one ``Sequence[Relation]`` shared by every window, or a
    per-window list of them — enabling the does-this-link-exist check.
    ``weights`` (optional) is the per-window staleness denominator vector
    the aggregation engine actually used (one array per window).
    ``events`` (optional) are flight-recorder events from the executed run
    (non-payload categories are ignored). ``pending_start`` seeds the age
    ledger when the audited sequence does not begin at window 0.

    Results also land on the active recorder: ``audit.windows`` /
    ``audit.payloads`` / ``audit.violations`` counters and an
    ``audit.hops_per_payload`` histogram.
    """
    report = AuditReport(n_windows=len(programs))
    if not programs:
        return report
    windows = [wp.window for wp in programs]
    if windows != list(range(windows[0], windows[0] + len(programs))):
        raise ValueError(
            f"programs must be consecutive windows, got {windows}"
        )
    per_window_slots: List[Optional[Sequence]] = [None] * len(programs)
    if slots is not None:
        first = slots[0] if len(slots) > 0 else None
        if first is not None and hasattr(first, "edge_list"):
            per_window_slots = [slots] * len(programs)  # shared slot list
        else:
            if len(slots) != len(programs):
                raise ValueError(
                    "per-window slots must align 1:1 with programs"
                )
            per_window_slots = list(slots)
    if weights is not None and len(weights) != len(programs):
        raise ValueError("per-window weights must align 1:1 with programs")

    pending = dict(pending_start or {})
    first_window = programs[0].window
    for i, wp in enumerate(programs):
        wslots = per_window_slots[i]
        _replay_uplink(wp, wslots, report)
        _check_downlink(wp, wslots, report)
        if i > 0 or first_window == 0 or pending_start is not None:
            pending = _check_ledger(wp, pending, report)
        else:
            pending = dict(wp.residual)
        _check_weights(
            wp, decay, None if weights is None else weights[i], report
        )
    if events is not None:
        _check_events(programs, events, report)

    rec = telemetry.get_recorder()
    rec.counter("audit.windows", len(programs))
    rec.counter("audit.payloads", report.n_payloads)
    rec.counter("audit.violations", len(report.violations))
    return report


def audit_recorder(
    rec: Recorder,
    programs: Sequence,
    *,
    decay: float = 1.0,
    slots: Optional[Sequence] = None,
    weights: Optional[Sequence] = None,
) -> AuditReport:
    """Audit an executed run: the planned programs against the payload
    lifecycle events ``rec`` captured while executing them (requires the
    run to have traced with ``record_scope(tracing=True)``)."""
    return audit_window_programs(
        programs,
        decay=decay,
        slots=slots,
        weights=weights,
        events=rec.events,
    )


# ---------------------------------------------------------------------------
# CI gate: audit a small ground-segment plan end to end
# ---------------------------------------------------------------------------

def _ci_smoke(windows: int, report_prefix: Optional[str]) -> int:
    """Plan a small 2-plane Walker + 2-ground-station constellation, run
    the pipelined router for a few windows (with one satellite outage to
    exercise the carry/age ledger), and audit the result. Zero violations
    is the gate; the optional mission report captures the evidence."""
    from repro.constellation import contact_plan, orbits
    from repro.groundseg import routing

    n_sats, n_gs = 6, 2
    n = n_sats + n_gs
    sinks = frozenset(range(n_sats, n))
    geom = orbits.WalkerDelta(
        total=n_sats, planes=2, altitude_km=8062.0, inclination_deg=60.0
    )
    gs = [
        orbits.GroundStation(0.0, 0.0, name="equator"),
        orbits.GroundStation(45.0, 120.0, name="midlat"),
    ]
    plan = contact_plan.build_contact_plan(
        geom,
        duration_s=geom.period_s,
        step_s=geom.period_s / 10,
        ground_stations=gs,
        max_range_km=16_000.0,
    )
    with telemetry.record_scope(tracing=True) as rec:
        sched = plan.schedule(antennas=2, payload_bytes=1 << 20)
        rels = list(sched.tdm)
        router = routing.MultiWindowRouter(
            n, sinks, max_staleness_windows=2, pipeline_depth=2
        )
        programs = []
        for w in range(windows):
            alive = set(range(n)) - ({1} if w == 2 else set())
            programs.append(router.plan_window(rels, alive=alive))
        audit = audit_window_programs(programs, decay=0.5, slots=rels)
        print(
            f"audited {audit.n_windows} windows / {audit.n_payloads} "
            f"payloads / {audit.n_hops} hops: "
            f"{len(audit.violations)} violation(s)"
        )
        for v in audit.violations:
            print(f"  {v}")
        if report_prefix:
            from repro.telemetry.report import write_report

            md, js = write_report(
                report_prefix, rec, audit=audit,
                title="groundseg audit smoke",
            )
            print(f"wrote mission report to {md} and {js}")
    return 0 if audit.ok else 1


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    p.add_argument(
        "--ci-smoke", action="store_true",
        help="audit a small groundseg plan end to end (CI gate)",
    )
    p.add_argument("--windows", type=int, default=5)
    p.add_argument(
        "--report", default=None, metavar="PREFIX",
        help="also write PREFIX.md / PREFIX.json mission report",
    )
    args = p.parse_args(argv)
    if not args.ci_smoke:
        p.error("nothing to do: pass --ci-smoke")
    return _ci_smoke(args.windows, args.report)


__all__ = (
    "AuditError",
    "AuditReport",
    "AuditViolation",
    "PayloadTrail",
    "audit_recorder",
    "audit_window_programs",
    "expected_sink_weights",
)

if __name__ == "__main__":
    raise SystemExit(main())
