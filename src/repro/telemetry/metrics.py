"""Metrics registry: gauges and fixed-bucket histograms on the recorder.

Extends the counters-only :class:`~repro.telemetry.recorder.Recorder` with
the other two metric kinds a mission-control view needs (ISSUE 9):

- **Gauges** — last-value-wins samples (`optimizer.warm_start.hit_rate`,
  `groundseg.router.table_cache.hit_rate`): one dict write on the host,
  same default-on zero-device-sync discipline as counters.
- **Histograms** — fixed-bucket distributions (`groundseg.router.
  queue_depth`, `contact.link_utilization`, `groundseg.router.
  payload_age`): an :meth:`Histogram.observe` is one ``bisect`` plus two
  dict-free list/scalar updates; bucket layouts are fixed at first
  observation so recording never allocates per sample.

Percentile summaries surface in
:func:`repro.telemetry.export.metrics_snapshot` and the Prometheus-style
text exposition in :func:`repro.telemetry.export.prometheus_text`; the
mission-report generator (:mod:`repro.telemetry.report`) renders both.

Like :mod:`repro.telemetry.recorder`, this module is stdlib-only by
design: :mod:`repro.core` and the constellation scheduler instrument
through it, so it must sit below everything jax-flavored.
"""

from __future__ import annotations

import bisect
import math
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from repro.telemetry.recorder import Recorder, get_recorder

# Bucket presets (upper bounds, ascending; +Inf overflow is implicit).
# Small-integer counts: queue depths, hop counts, batch multiplicities.
COUNT_BUCKETS: Tuple[float, ...] = (0, 1, 2, 4, 8, 16, 32, 64, 128, 256)
# Window-age style small integers where 0/1/2/3 each matter.
AGE_BUCKETS: Tuple[float, ...] = (0, 1, 2, 3, 4, 6, 8, 12, 16)
# Fractions in [0, 1]: link utilization, cache hit rates sampled over time.
UNIT_BUCKETS: Tuple[float, ...] = tuple(x / 10 for x in range(1, 11))
# Log-spaced positive magnitudes: seconds, megabytes — anything spanning
# orders of magnitude.
LOG_BUCKETS: Tuple[float, ...] = tuple(
    10.0**e for e in range(-4, 7)
)
DEFAULT_BUCKETS = LOG_BUCKETS


class Histogram:
    """A fixed-bucket histogram: counts per bucket plus exact sum/min/max.

    ``bounds`` are inclusive upper bounds sorted ascending; values above
    the last bound land in the implicit overflow bucket. Quantiles are
    estimated by linear interpolation inside the containing bucket and
    clamped to the exact observed ``[min, max]``, so single-valued
    histograms report exact percentiles.
    """

    __slots__ = ("bounds", "counts", "count", "total", "vmin", "vmax")

    def __init__(self, bounds: Iterable[float] = DEFAULT_BUCKETS):
        bs = tuple(float(b) for b in bounds)
        if not bs or any(a >= b for a, b in zip(bs, bs[1:])):
            raise ValueError(
                f"histogram bounds must be non-empty and ascending, got {bs}"
            )
        self.bounds = bs
        self.counts = [0] * (len(bs) + 1)  # last = overflow (> bounds[-1])
        self.count = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf

    def observe(self, value: float) -> None:
        v = float(value)
        self.counts[bisect.bisect_left(self.bounds, v)] += 1
        self.count += 1
        self.total += v
        if v < self.vmin:
            self.vmin = v
        if v > self.vmax:
            self.vmax = v

    def cumulative(self) -> List[int]:
        """Prometheus-style cumulative bucket counts (last == count)."""
        out, acc = [], 0
        for c in self.counts:
            acc += c
            out.append(acc)
        return out

    def quantile(self, q: float) -> float:
        """Estimated q-quantile (q in [0, 1]); NaN on an empty histogram."""
        if self.count == 0:
            return math.nan
        rank = q * self.count
        acc = 0
        for i, c in enumerate(self.counts):
            if c == 0:
                continue
            if acc + c >= rank:
                # linear interpolation across the containing bucket; the
                # overflow bucket has no upper bound, so report the max
                if i >= len(self.bounds):
                    return self.vmax
                hi = self.bounds[i]
                lo = self.bounds[i - 1] if i > 0 else min(self.vmin, hi)
                frac = (rank - acc) / c
                est = lo + (hi - lo) * frac
                return min(max(est, self.vmin), self.vmax)
            acc += c
        return self.vmax

    def summary(self) -> Dict[str, float]:
        """JSON-able digest: count/sum/mean/min/max plus p50/p90/p99."""
        if self.count == 0:
            return {"count": 0, "sum": 0.0}
        return {
            "count": self.count,
            "sum": self.total,
            "mean": self.total / self.count,
            "min": self.vmin,
            "max": self.vmax,
            "p50": self.quantile(0.50),
            "p90": self.quantile(0.90),
            "p99": self.quantile(0.99),
        }


# ---------------------------------------------------------------------------
# Registry operations on the active recorder
# ---------------------------------------------------------------------------

def observe(
    name: str,
    value: float,
    buckets: Optional[Sequence[float]] = None,
    rec: Optional[Recorder] = None,
) -> None:
    """Record one histogram sample on the active recorder (default-on).

    The first observation of ``name`` fixes its bucket layout (``buckets``
    or :data:`DEFAULT_BUCKETS`); later calls reuse it, so hot loops pay
    one bisect per sample and zero allocation."""
    rec = rec or get_recorder()
    h = rec.hists.get(name)
    if h is None:
        h = rec.hists[name] = Histogram(
            DEFAULT_BUCKETS if buckets is None else buckets
        )
    h.observe(value)


def set_gauge(
    name: str, value: float, rec: Optional[Recorder] = None
) -> None:
    """Set a last-value-wins gauge on the active recorder (default-on)."""
    (rec or get_recorder()).gauges[name] = float(value)


def get_gauge(
    name: str, default: float = math.nan, rec: Optional[Recorder] = None
) -> float:
    return (rec or get_recorder()).gauges.get(name, default)


def get_histogram(
    name: str, rec: Optional[Recorder] = None
) -> Optional[Histogram]:
    return (rec or get_recorder()).hists.get(name)


def ratio_gauge(
    name: str,
    numerator: float,
    denominator: float,
    rec: Optional[Recorder] = None,
) -> None:
    """Set ``name`` to ``numerator / denominator`` (skip on zero denom) —
    the hit-rate idiom: callers pass two counter values and the gauge
    always reflects the latest totals."""
    if denominator > 0:
        set_gauge(name, numerator / denominator, rec=rec)


def histograms_summary(
    rec: Optional[Recorder] = None,
) -> Dict[str, Dict[str, float]]:
    """Per-histogram percentile digests, sorted by name (for snapshots)."""
    rec = rec or get_recorder()
    return {name: rec.hists[name].summary() for name in sorted(rec.hists)}


__all__ = (
    "AGE_BUCKETS",
    "COUNT_BUCKETS",
    "DEFAULT_BUCKETS",
    "Histogram",
    "LOG_BUCKETS",
    "UNIT_BUCKETS",
    "get_gauge",
    "get_histogram",
    "histograms_summary",
    "observe",
    "ratio_gauge",
    "set_gauge",
)
