"""Mamba-2 (SSD — state-space duality) mixer, chunked, in pure JAX.

The chunked SSD algorithm (Dao & Gu, arXiv:2405.21060) splits the sequence
into MXU-friendly chunks: inside a chunk the recurrence is computed as
attention-like matmuls against the decay kernel L; across chunks a small
recurrent state (B, H, P, N) is carried by ``lax.scan``. This is both the
memory-sane XLA path and the exact structure of the Pallas kernel
(:mod:`repro.kernels.ssd_scan`); the sequential-scan oracle lives in
``kernels/ssd_scan/ref.py``.

Layout: x (B,S,D) -> z,xc (B,S,di), B,C (B,S,G,N), dt (B,S,Hm);
heads Hm = di / P share B/C within each of the G groups.
"""

from __future__ import annotations

import math
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.launch.sharding import shard_activation
from repro.models.config import ModelConfig
from repro.models.layers import dtype_of, rmsnorm, truncated_normal


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------

def init_mamba(key, cfg: ModelConfig) -> Tuple[Dict, Dict]:
    mb = cfg.mamba
    D = cfg.d_model
    di = mb.d_inner(D)
    Hm = mb.n_heads(D)
    G, N, K = mb.n_groups, mb.d_state, mb.d_conv
    dt = dtype_of(cfg.param_dtype)
    ks = jax.random.split(key, 8)
    std = D ** -0.5

    # dt bias: inverse-softplus of dt sampled log-uniform in [dt_min, dt_max]
    u = jax.random.uniform(ks[6], (Hm,))
    dt_init = jnp.exp(
        u * (math.log(mb.dt_max) - math.log(mb.dt_min)) + math.log(mb.dt_min)
    )
    dt_bias = dt_init + jnp.log(-jnp.expm1(-dt_init))  # softplus^-1

    p = {
        "wz": truncated_normal(ks[0], (D, di), std, dt),
        "wx": truncated_normal(ks[1], (D, di), std, dt),
        "wB": truncated_normal(ks[2], (D, G, N), std, dt),
        "wC": truncated_normal(ks[3], (D, G, N), std, dt),
        "wdt": truncated_normal(ks[4], (D, Hm), std, dt),
        "dt_bias": dt_bias.astype(jnp.float32),
        # separate depthwise convs per stream (x / B / C): mathematically
        # identical to the joint conv over concat([x,B,C]) but keeps each
        # stream's sharding intact (concat+slice across a model-sharded dim
        # would force GSPMD reshards — see DESIGN.md §3 adaptation notes).
        "conv_wx": truncated_normal(ks[5], (K, di), di ** -0.5, dt),
        "conv_bx": jnp.zeros((di,), dtype=dt),
        "conv_wB": truncated_normal(jax.random.fold_in(ks[5], 1), (K, G * N), (G * N) ** -0.5, dt),
        "conv_bB": jnp.zeros((G * N,), dtype=dt),
        "conv_wC": truncated_normal(jax.random.fold_in(ks[5], 2), (K, G * N), (G * N) ** -0.5, dt),
        "conv_bC": jnp.zeros((G * N,), dtype=dt),
        "A_log": jnp.log(
            jax.random.uniform(ks[7], (Hm,), minval=1.0, maxval=16.0)
        ).astype(jnp.float32),
        "D_skip": jnp.ones((Hm,), dtype=jnp.float32),
        "norm": jnp.zeros((di,), dtype=dt),
        "out": truncated_normal(jax.random.fold_in(key, 99), (di, D), di ** -0.5, dt),
    }
    s = {
        "wz": ("embed", "mamba_inner"),
        "wx": ("embed", "mamba_inner"),
        "wB": ("embed", "groups", "state"),
        "wC": ("embed", "groups", "state"),
        "wdt": ("embed", "mamba_heads"),
        "dt_bias": ("mamba_heads",),
        "conv_wx": ("conv_k", "mamba_inner"),
        "conv_bx": ("mamba_inner",),
        "conv_wB": ("conv_k", None),
        "conv_bB": (None,),
        "conv_wC": ("conv_k", None),
        "conv_bC": (None,),
        "A_log": ("mamba_heads",),
        "D_skip": ("mamba_heads",),
        "norm": ("mamba_inner",),
        "out": ("mamba_inner", "embed"),
    }
    return p, s


# ---------------------------------------------------------------------------
# causal depthwise conv
# ---------------------------------------------------------------------------

def causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """x: (B,S,C); w: (K,C) depthwise. Left-padded causal convolution."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(
        xp[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(K)
    )
    return out + b[None, None, :]


def conv_step(x_t: jax.Array, conv_state: jax.Array, w: jax.Array, b: jax.Array):
    """Single decode step. x_t: (B,C); conv_state: (B,K-1,C). Returns
    (out (B,C), new_state)."""
    window = jnp.concatenate([conv_state, x_t[:, None, :]], axis=1)  # (B,K,C)
    out = jnp.einsum("bkc,kc->bc", window, w) + b[None, :]
    return out, window[:, 1:, :]


# ---------------------------------------------------------------------------
# chunked SSD forward (train / prefill)
# ---------------------------------------------------------------------------

class MambaCache(NamedTuple):
    ssm: jax.Array        # (B, Hm, P, N) fp32 recurrent state
    conv: jax.Array       # (B, K-1, conv_dim)


def _project(p: Dict, x: jax.Array, cfg: ModelConfig):
    cdt = x.dtype
    z = jnp.einsum("bsd,di->bsi", x, p["wz"].astype(cdt))
    xc = jnp.einsum("bsd,di->bsi", x, p["wx"].astype(cdt))
    Bv = jnp.einsum("bsd,dgn->bsgn", x, p["wB"].astype(cdt))
    Cv = jnp.einsum("bsd,dgn->bsgn", x, p["wC"].astype(cdt))
    dt_raw = jnp.einsum("bsd,dh->bsh", x, p["wdt"].astype(cdt))
    z = shard_activation(z, ("batch", "seq", "mamba_inner"))
    xc = shard_activation(xc, ("batch", "seq", "mamba_inner"))
    Bv = shard_activation(Bv, ("batch", "seq", None, None))
    Cv = shard_activation(Cv, ("batch", "seq", None, None))
    dt_raw = shard_activation(dt_raw, ("batch", "seq", "mamba_heads"))
    return z, xc, Bv, Cv, dt_raw


def _conv_mix(p, xc, Bv, Cv, cfg: ModelConfig):
    """Per-stream causal convs (x / B / C) then SiLU (see init_mamba note)."""
    B_, S = xc.shape[:2]
    mb = cfg.mamba
    G, N = mb.n_groups, mb.d_state
    cdt = xc.dtype
    xc = jax.nn.silu(causal_conv(xc, p["conv_wx"].astype(cdt), p["conv_bx"].astype(cdt)))
    Bf = jax.nn.silu(causal_conv(
        Bv.reshape(B_, S, G * N), p["conv_wB"].astype(cdt), p["conv_bB"].astype(cdt)
    ))
    Cf = jax.nn.silu(causal_conv(
        Cv.reshape(B_, S, G * N), p["conv_wC"].astype(cdt), p["conv_bC"].astype(cdt)
    ))
    xc = shard_activation(xc, ("batch", "seq", "mamba_inner"))
    return xc, Bf.reshape(B_, S, G, N), Cf.reshape(B_, S, G, N)


def _expand_groups(t: jax.Array, Hm: int) -> jax.Array:
    """(B,Q,G,N) -> (B,Q,Hm,N) by broadcasting each group over its heads."""
    B_, Q, G, N = t.shape
    r = Hm // G
    return jnp.broadcast_to(t[:, :, :, None, :], (B_, Q, G, r, N)).reshape(
        B_, Q, Hm, N
    )


def ssd_chunked(
    xh: jax.Array,      # (B, S, Hm, P)
    dt: jax.Array,      # (B, S, Hm) fp32 (post softplus)
    A: jax.Array,       # (Hm,) fp32 negative
    Bv: jax.Array,      # (B, S, G, N)
    Cv: jax.Array,      # (B, S, G, N)
    chunk: int,
    init_state: Optional[jax.Array] = None,
    remat_body: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD scan. Returns (y (B,S,Hm,P), final_state (B,Hm,P,N))."""
    B_, S, Hm, P = xh.shape
    G, N = Bv.shape[2], Bv.shape[3]
    nc = S // chunk
    assert nc * chunk == S, (S, chunk)

    xh_c = xh.reshape(B_, nc, chunk, Hm, P)
    dt_c = dt.reshape(B_, nc, chunk, Hm)
    Bv_c = Bv.reshape(B_, nc, chunk, G, N)
    Cv_c = Cv.reshape(B_, nc, chunk, G, N)

    def body(state, inputs):
        xq, dtq, Bq, Cq = inputs          # (B,Q,H,P), (B,Q,H), (B,Q,G,N) x2
        state = shard_activation(state, ("batch", "mamba_heads", None, None))
        xq = shard_activation(xq, ("batch", None, "mamba_heads", None))
        dtq = shard_activation(dtq, ("batch", None, "mamba_heads"))
        Bh = _expand_groups(Bq, Hm)       # (B,Q,H,N)
        Ch = _expand_groups(Cq, Hm)
        Bh = shard_activation(Bh, ("batch", None, "mamba_heads", None))
        Ch = shard_activation(Ch, ("batch", None, "mamba_heads", None))
        l = dtq * A[None, None, :]        # (B,Q,H) negative decays
        cum = jnp.cumsum(l, axis=1)       # inclusive within-chunk cumsum
        decay_chunk = jnp.exp(cum[:, -1])                      # (B,H)
        # inter-chunk: Y_t += exp(cum_t) * C_t . S_prev
        y_inter = jnp.einsum(
            "bqhn,bhpn->bqhp", Ch.astype(jnp.float32), state
        ) * jnp.exp(cum)[..., None]
        # intra-chunk: W[t,s] = (C_t.B_s) exp(cum_t - cum_s) dt_s for s<=t
        CB = jnp.einsum(
            "bqhn,bshn->bhqs", Ch, Bh, preferred_element_type=jnp.float32
        )
        cum_t = cum.transpose(0, 2, 1)    # (B,H,Q)
        Ldec = jnp.exp(cum_t[:, :, :, None] - cum_t[:, :, None, :])
        tri = jnp.tril(jnp.ones((chunk, chunk), dtype=bool))
        W = jnp.where(tri[None, None], CB * Ldec, 0.0)
        W = W * dtq.transpose(0, 2, 1)[:, :, None, :]          # weight dt_s
        y_intra = jnp.einsum(
            "bhqs,bshp->bqhp", W.astype(xq.dtype), xq,
            preferred_element_type=jnp.float32,
        )
        # state update: S = decay_chunk*S + sum_s exp(cum_Q - cum_s) dt_s B_s x_s
        decay_to_end = jnp.exp(cum[:, -1:, :] - cum) * dtq     # (B,Q,H)
        dB = Bh.astype(jnp.float32) * decay_to_end[..., None]  # (B,Q,H,N)
        new_state = decay_chunk[:, :, None, None] * state + jnp.einsum(
            "bqhn,bqhp->bhpn", dB, xh_f32(xq)
        )
        new_state = shard_activation(new_state, ("batch", "mamba_heads", None, None))
        y = (y_inter + y_intra).astype(xq.dtype)
        y = shard_activation(y, ("batch", None, "mamba_heads", None))
        return new_state, y

    def xh_f32(t):
        return t.astype(jnp.float32)

    if init_state is None:
        init_state = jnp.zeros((B_, Hm, P, N), dtype=jnp.float32)
    fn = jax.checkpoint(body) if remat_body else body
    final_state, ys = jax.lax.scan(
        fn,
        init_state,
        (
            xh_c.transpose(1, 0, 2, 3, 4),
            dt_c.transpose(1, 0, 2, 3),
            Bv_c.transpose(1, 0, 2, 3, 4),
            Cv_c.transpose(1, 0, 2, 3, 4),
        ),
    )
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B_, S, Hm, P)
    return y, final_state


def mamba_forward(p: Dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Training mixer: project -> conv -> SSD -> gate -> out. x: (B,S,D)."""
    mb = cfg.mamba
    D = cfg.d_model
    di, Hm = mb.d_inner(D), mb.n_heads(D)
    P = mb.head_dim
    B_, S, _ = x.shape

    z, xc, Bv, Cv, dt_raw = _project(p, x, cfg)
    xc, Bv, Cv = _conv_mix(p, xc, Bv, Cv, cfg)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"][None, None])
    A = -jnp.exp(p["A_log"])
    xh = xc.reshape(B_, S, Hm, P)

    chunk = min(mb.chunk, S)
    y, _ = ssd_chunked(
        xh, dt, A, Bv, Cv, chunk, remat_body=cfg.remat != "none"
    )
    y = y + xh * p["D_skip"][None, None, :, None].astype(y.dtype)
    y = y.reshape(B_, S, di)
    y = rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype), p["norm"], cfg.norm_eps)
    return jnp.einsum("bsi,id->bsd", y, p["out"].astype(y.dtype))


def mamba_prefill(p: Dict, x: jax.Array, cfg: ModelConfig) -> Tuple[jax.Array, MambaCache]:
    """Prefill: like forward but also returns the true conv tail state."""
    mb = cfg.mamba
    D = cfg.d_model
    di, Hm = mb.d_inner(D), mb.n_heads(D)
    P, N, G = mb.head_dim, mb.d_state, mb.n_groups
    B_, S, _ = x.shape
    z, xc0, Bv0, Cv0, dt_raw = _project(p, x, cfg)
    # decode conv state: last K-1 PRE-conv inputs, concat layout [x|B|C]
    cat = jnp.concatenate(
        [xc0, Bv0.reshape(B_, S, G * N), Cv0.reshape(B_, S, G * N)], axis=-1
    )
    K = mb.d_conv
    conv_tail = cat[:, S - (K - 1) :, :]
    xc, Bv, Cv = _conv_mix(p, xc0, Bv0, Cv0, cfg)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"][None, None])
    A = -jnp.exp(p["A_log"])
    xh = xc.reshape(B_, S, Hm, P)
    y, final_state = ssd_chunked(xh, dt, A, Bv, Cv, min(mb.chunk, S))
    y = y + xh * p["D_skip"][None, None, :, None].astype(y.dtype)
    y = y.reshape(B_, S, di)
    y = rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype), p["norm"], cfg.norm_eps)
    out = jnp.einsum("bsi,id->bsd", y, p["out"].astype(y.dtype))
    return out, MambaCache(ssm=final_state, conv=conv_tail)


def mamba_decode_step(
    p: Dict, x_t: jax.Array, cache: MambaCache, cfg: ModelConfig
) -> Tuple[jax.Array, MambaCache]:
    """One recurrent step. x_t: (B,1,D) -> (B,1,D)."""
    mb = cfg.mamba
    D = cfg.d_model
    di, Hm = mb.d_inner(D), mb.n_heads(D)
    P, N, G = mb.head_dim, mb.d_state, mb.n_groups
    B_ = x_t.shape[0]
    z, xc, Bv, Cv, dt_raw = _project(p, x_t, cfg)
    cat = jnp.concatenate(
        [xc[:, 0], Bv.reshape(B_, 1, G * N)[:, 0], Cv.reshape(B_, 1, G * N)[:, 0]],
        axis=-1,
    )
    window = jnp.concatenate([cache.conv, cat[:, None, :]], axis=1)  # (B,K,C)
    new_conv = window[:, 1:, :]
    # per-stream convs applied to the shared [x|B|C] window
    wx = window[..., :di]
    wB = window[..., di : di + G * N]
    wC = window[..., di + G * N :]
    xc = jax.nn.silu(
        jnp.einsum("bkc,kc->bc", wx, p["conv_wx"].astype(cat.dtype))
        + p["conv_bx"].astype(cat.dtype)[None]
    )
    Bv = jax.nn.silu(
        jnp.einsum("bkc,kc->bc", wB, p["conv_wB"].astype(cat.dtype))
        + p["conv_bB"].astype(cat.dtype)[None]
    ).reshape(B_, G, N)
    Cv = jax.nn.silu(
        jnp.einsum("bkc,kc->bc", wC, p["conv_wC"].astype(cat.dtype))
        + p["conv_bC"].astype(cat.dtype)[None]
    ).reshape(B_, G, N)
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"][None])
    A = -jnp.exp(p["A_log"])

    xh = xc.reshape(B_, Hm, P)
    r = Hm // G
    Bh = jnp.broadcast_to(Bv[:, :, None, :], (B_, G, r, N)).reshape(B_, Hm, N)
    Ch = jnp.broadcast_to(Cv[:, :, None, :], (B_, G, r, N)).reshape(B_, Hm, N)
    decay = jnp.exp(dt * A[None])                                  # (B,H)
    new_ssm = decay[:, :, None, None] * cache.ssm + jnp.einsum(
        "bhn,bhp,bh->bhpn", Bh.astype(jnp.float32), xh.astype(jnp.float32), dt
    )
    y = jnp.einsum("bhn,bhpn->bhp", Ch.astype(jnp.float32), new_ssm)
    y = y + xh.astype(jnp.float32) * p["D_skip"][None, :, None]
    y = y.reshape(B_, 1, di).astype(x_t.dtype)
    y = rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype), p["norm"], cfg.norm_eps)
    out = jnp.einsum("bsi,id->bsd", y, p["out"].astype(y.dtype))
    return out, MambaCache(ssm=new_ssm, conv=new_conv)
