"""The LM decoder stack: composable blocks covering every assigned family.

A config is compiled (in Python) to a list of :class:`LayerDesc` per *scan
unit*; units are homogeneous, so the whole depth is a single ``lax.scan``
over stacked params (O(1) HLO in depth):

- dense (granite/qwen2/qwen2-vl):  unit = [attn+mlp],        L units
- gemma2:                          unit = [attn(local)+mlp,
                                           attn(global)+mlp], L/2 units
- moe (qwen3-moe/kimi-k2):         unit = [attn+moe],         L units
- ssm (mamba2):                    unit = [mamba],            L units
- hybrid (jamba):                  unit = [attn+mlp, (mamba+moe, mamba+mlp)
                                           alternating x7],   L/8 units
- whisper decoder:                 unit = [attn+cross+mlp],   L units

Caches: per attention layer a KV ring buffer (length = window for local
layers — a sliding-window cache — else the max sequence length), per mamba
layer the (ssm, conv) recurrent state, per cross-attn layer the frozen
encoder KV. Decode scans units with the stacked cache as scan xs/ys.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.launch.sharding import shard_activation
from repro.models import mamba2 as mamba_lib
from repro.models import moe as moe_lib
from repro.models.attention import (
    AttnSpec,
    flash_attention_decode,
    flash_attention_train,
)
from repro.models.config import ModelConfig
from repro.models.layers import (
    apply_mrope,
    apply_rope,
    dtype_of,
    embed_tokens,
    init_embedding,
    init_mlp,
    init_rmsnorm,
    lm_logits,
    mlp_apply,
    rmsnorm,
    stack_params,
    truncated_normal,
)

Params = Dict[str, Any]


@dataclass(frozen=True)
class LayerDesc:
    mixer: str                  # "attn" | "mamba"
    local: bool = False         # sliding-window attention
    ffn: Optional[str] = None   # "dense" | "moe" | None
    cross: bool = False         # cross-attention (whisper decoder)


def scan_unit(cfg: ModelConfig) -> List[LayerDesc]:
    """The per-unit layer pattern for this config (see module docstring)."""
    if cfg.family == "ssm":
        return [LayerDesc("mamba", ffn=None if cfg.no_ffn else "dense")]
    if cfg.family == "hybrid":
        period = cfg.attn_every
        descs = []
        for j in range(period):
            mixer = "attn" if j == 0 else "mamba"
            ffn = "moe" if cfg.ffn_is_moe(j) else "dense"
            descs.append(
                LayerDesc(mixer, local=cfg.layer_is_local(j) or cfg.force_local, ffn=ffn)
            )
        return descs
    if cfg.local_global_alternate:
        return [
            LayerDesc("attn", local=True, ffn="moe" if cfg.ffn_is_moe(0) else "dense"),
            LayerDesc("attn", local=False, ffn="moe" if cfg.ffn_is_moe(1) else "dense"),
        ]
    ffn = "moe" if (cfg.moe is not None and cfg.moe.every == 1) else "dense"
    return [LayerDesc("attn", local=cfg.force_local, ffn=ffn, cross=cfg.enc_dec)]


def n_units(cfg: ModelConfig) -> int:
    return cfg.n_layers // len(scan_unit(cfg))


# ---------------------------------------------------------------------------
# attention sub-layer
# ---------------------------------------------------------------------------

def init_attention(key, cfg: ModelConfig) -> Tuple[Params, Dict]:
    D, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    dt = dtype_of(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    std = D ** -0.5
    p = {
        "wq": truncated_normal(ks[0], (D, H, hd), std, dt),
        "wk": truncated_normal(ks[1], (D, KV, hd), std, dt),
        "wv": truncated_normal(ks[2], (D, KV, hd), std, dt),
        "wo": truncated_normal(ks[3], (H, hd, D), (H * hd) ** -0.5, dt),
    }
    s = {
        "wq": ("embed", "heads", "head_dim"),
        "wk": ("embed", "kv_heads", "head_dim"),
        "wv": ("embed", "kv_heads", "head_dim"),
        "wo": ("heads", "head_dim", "embed"),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H, hd), dtype=dt)
        p["bk"] = jnp.zeros((KV, hd), dtype=dt)
        p["bv"] = jnp.zeros((KV, hd), dtype=dt)
        s["bq"] = ("heads", "head_dim")
        s["bk"] = ("kv_heads", "head_dim")
        s["bv"] = ("kv_heads", "head_dim")
    return p, s


def _qkv(p: Params, x: jax.Array, cfg: ModelConfig):
    cdt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(cdt))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(cdt))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(cdt))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(cdt)[None, None]
        k = k + p["bk"].astype(cdt)[None, None]
        v = v + p["bv"].astype(cdt)[None, None]
    q = shard_activation(q, ("batch", "seq", "heads", None))
    k = shard_activation(k, ("batch", "seq", "kv_heads", None))
    v = shard_activation(v, ("batch", "seq", "kv_heads", None))
    return q, k, v


def _rope_qk(q, k, positions, cfg: ModelConfig):
    if cfg.mrope_sections is not None:
        q = apply_mrope(q, positions, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, positions, cfg.rope_theta, cfg.mrope_sections)
    else:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k


def _attn_spec(cfg: ModelConfig, desc: LayerDesc, causal: bool = True) -> AttnSpec:
    return AttnSpec(
        causal=causal,
        window=cfg.sliding_window if desc.local else None,
        softcap=cfg.attn_softcap,
        block_q=cfg.attn_block_q,
        block_k=cfg.attn_block_k,
    )


def attn_train(
    p: Params, x: jax.Array, positions, cfg: ModelConfig, desc: LayerDesc,
    causal: bool = True,
) -> jax.Array:
    q, k, v = _qkv(p, x, cfg)
    q, k = _rope_qk(q, k, positions, cfg)
    out = flash_attention_train(q, k, v, _attn_spec(cfg, desc, causal))
    out = shard_activation(out, ("batch", "seq", "heads", None))
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(out.dtype))


def cross_attn_train(p: Params, x: jax.Array, enc_kv, cfg: ModelConfig) -> jax.Array:
    """Cross-attention against precomputed encoder K/V (no rope, no mask)."""
    cdt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(cdt))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(cdt)[None, None]
    k, v = enc_kv
    spec = AttnSpec(causal=False, window=None, softcap=cfg.attn_softcap,
                    block_q=cfg.attn_block_q, block_k=cfg.attn_block_k)
    out = flash_attention_train(q, k, v, spec)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(out.dtype))


def enc_kv_for_cross(p: Params, enc_out: jax.Array, cfg: ModelConfig):
    cdt = enc_out.dtype
    k = jnp.einsum("bsd,dhk->bshk", enc_out, p["wk"].astype(cdt))
    v = jnp.einsum("bsd,dhk->bshk", enc_out, p["wv"].astype(cdt))
    if cfg.qkv_bias:
        k = k + p["bk"].astype(cdt)[None, None]
        v = v + p["bv"].astype(cdt)[None, None]
    return k, v


# ---------------------------------------------------------------------------
# unit (scan body) param init
# ---------------------------------------------------------------------------

def init_unit(key, cfg: ModelConfig) -> Tuple[Params, Dict]:
    descs = scan_unit(cfg)
    p, s = {}, {}
    for j, d in enumerate(descs):
        kj = jax.random.fold_in(key, j)
        name = f"L{j}"
        lp, ls = {}, {}
        lp["ln"], ls["ln"] = init_rmsnorm(cfg.d_model, dtype_of(cfg.param_dtype))
        if d.mixer == "attn":
            lp["attn"], ls["attn"] = init_attention(jax.random.fold_in(kj, 0), cfg)
        else:
            lp["mamba"], ls["mamba"] = mamba_lib.init_mamba(
                jax.random.fold_in(kj, 1), cfg
            )
        if d.cross:
            lp["cross_ln"], ls["cross_ln"] = init_rmsnorm(
                cfg.d_model, dtype_of(cfg.param_dtype)
            )
            lp["cross"], ls["cross"] = init_attention(jax.random.fold_in(kj, 2), cfg)
        if d.ffn is not None:
            lp["ln2"], ls["ln2"] = init_rmsnorm(cfg.d_model, dtype_of(cfg.param_dtype))
            if d.ffn == "moe":
                lp["ffn"], ls["ffn"] = moe_lib.init_moe(jax.random.fold_in(kj, 3), cfg)
            else:
                lp["ffn"], ls["ffn"] = init_mlp(
                    jax.random.fold_in(kj, 3), cfg, cfg.d_ff
                )
        p[name], s[name] = lp, ls
    return p, s


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------

class KVCache(NamedTuple):
    k: jax.Array   # (B, Sc, KV, hd)
    v: jax.Array


def layer_cache_len(cfg: ModelConfig, desc: LayerDesc, max_len: int) -> int:
    if desc.local and cfg.sliding_window is not None:
        return min(max_len, cfg.sliding_window)
    return max_len


def init_unit_cache(cfg: ModelConfig, batch: int, max_len: int) -> Dict:
    """Zero cache for ONE unit (to be stacked/broadcast over units)."""
    descs = scan_unit(cfg)
    cdt = dtype_of(cfg.compute_dtype)
    KV, hd = cfg.n_kv_heads, cfg.head_dim
    cache: Dict[str, Any] = {}
    for j, d in enumerate(descs):
        if d.mixer == "attn":
            L = layer_cache_len(cfg, d, max_len)
            cache[f"kv{j}"] = KVCache(
                k=jnp.zeros((batch, L, KV, hd), cdt),
                v=jnp.zeros((batch, L, KV, hd), cdt),
            )
            if d.cross:
                cache[f"cross{j}"] = KVCache(
                    k=jnp.zeros((batch, cfg.enc_frames, KV, hd), cdt),
                    v=jnp.zeros((batch, cfg.enc_frames, KV, hd), cdt),
                )
        else:
            mb = cfg.mamba
            Hm = mb.n_heads(cfg.d_model)
            conv_dim = mb.d_inner(cfg.d_model) + 2 * mb.n_groups * mb.d_state
            cache[f"mamba{j}"] = mamba_lib.MambaCache(
                ssm=jnp.zeros((batch, Hm, mb.head_dim, mb.d_state), jnp.float32),
                conv=jnp.zeros((batch, mb.d_conv - 1, conv_dim), cdt),
            )
    return cache


def cache_logical_specs(cfg: ModelConfig) -> Dict:
    descs = scan_unit(cfg)
    spec: Dict[str, Any] = {}
    for j, d in enumerate(descs):
        if d.mixer == "attn":
            spec[f"kv{j}"] = KVCache(
                k=("layers", "batch", "cache_seq", "kv_heads", None),
                v=("layers", "batch", "cache_seq", "kv_heads", None),
            )
            if d.cross:
                spec[f"cross{j}"] = KVCache(
                    k=("layers", "batch", "frames", "kv_heads", None),
                    v=("layers", "batch", "frames", "kv_heads", None),
                )
        else:
            spec[f"mamba{j}"] = mamba_lib.MambaCache(
                ssm=("layers", "batch", "mamba_heads", None, None),
                conv=("layers", "batch", None, None),  # tiny: keep whole
            )
    return spec


# ---------------------------------------------------------------------------
# model init
# ---------------------------------------------------------------------------

def init_params(key, cfg: ModelConfig) -> Tuple[Params, Dict]:
    k_embed, k_units, k_final, k_enc = jax.random.split(key, 4)
    params: Params = {}
    specs: Dict = {}
    params["embed"], specs["embed"] = init_embedding(k_embed, cfg)
    params["units"], specs["units"] = stack_params(
        k_units, n_units(cfg), lambda k: init_unit(k, cfg)
    )
    params["final_ln"], specs["final_ln"] = init_rmsnorm(
        cfg.d_model, dtype_of(cfg.param_dtype)
    )
    if cfg.enc_dec:
        params["encoder"], specs["encoder"] = init_encoder(k_enc, cfg)
    return params, specs


def init_encoder(key, cfg: ModelConfig) -> Tuple[Params, Dict]:
    def init_one(k):
        p, s = {}, {}
        p["ln"], s["ln"] = init_rmsnorm(cfg.d_model, dtype_of(cfg.param_dtype))
        p["attn"], s["attn"] = init_attention(jax.random.fold_in(k, 0), cfg)
        p["ln2"], s["ln2"] = init_rmsnorm(cfg.d_model, dtype_of(cfg.param_dtype))
        p["ffn"], s["ffn"] = init_mlp(jax.random.fold_in(k, 1), cfg, cfg.d_ff)
        return p, s

    p, s = {}, {}
    p["blocks"], s["blocks"] = stack_params(key, cfg.n_enc_layers, init_one)
    p["final_ln"], s["final_ln"] = init_rmsnorm(
        cfg.d_model, dtype_of(cfg.param_dtype)
    )
    return p, s


# ---------------------------------------------------------------------------
# encoder forward (whisper)
# ---------------------------------------------------------------------------

def encoder_forward(params: Params, enc_embeds: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Bidirectional encoder over stub frame embeddings (B, F, D)."""
    h = enc_embeds.astype(dtype_of(cfg.compute_dtype))
    B, F, _ = h.shape
    positions = jnp.broadcast_to(jnp.arange(F)[None], (B, F))

    def body(h, p):
        hn = rmsnorm(h, p["ln"], cfg.norm_eps)
        q, k, v = _qkv(p["attn"], hn, cfg)
        q, k = _rope_qk(q, k, positions, cfg)
        spec = AttnSpec(causal=False, softcap=cfg.attn_softcap,
                        block_q=cfg.attn_block_q, block_k=cfg.attn_block_k)
        a = flash_attention_train(q, k, v, spec)
        h = h + jnp.einsum("bshk,hkd->bsd", a, p["attn"]["wo"].astype(a.dtype))
        hn = rmsnorm(h, p["ln2"], cfg.norm_eps)
        h = h + mlp_apply(p["ffn"], hn, cfg)
        return h, None

    fn = jax.checkpoint(body) if cfg.remat == "full" else body
    h, _ = jax.lax.scan(fn, h, params["blocks"])
    return rmsnorm(h, params["final_ln"], cfg.norm_eps)


# ---------------------------------------------------------------------------
# decoder forward: train / prefill
# ---------------------------------------------------------------------------

def _unit_forward(
    h: jax.Array,
    unit_p: Params,
    positions,
    cfg: ModelConfig,
    enc_out: Optional[jax.Array],
    collect_cache: bool,
    max_len: int,
):
    """Apply one unit. Returns (h, aux_losses, cache_entries)."""
    descs = scan_unit(cfg)
    aux = {"moe_aux": jnp.zeros((), jnp.float32), "moe_zloss": jnp.zeros((), jnp.float32)}
    cache_out: Dict[str, Any] = {}
    for j, d in enumerate(descs):
        p = unit_p[f"L{j}"]
        hn = rmsnorm(h, p["ln"], cfg.norm_eps)
        if d.mixer == "attn":
            q, k, v = _qkv(p["attn"], hn, cfg)
            q, k = _rope_qk(q, k, positions, cfg)
            out = flash_attention_train(q, k, v, _attn_spec(cfg, d))
            out = shard_activation(out, ("batch", "seq", "heads", None))
            h = h + jnp.einsum("bshk,hkd->bsd", out, p["attn"]["wo"].astype(out.dtype))
            if collect_cache:
                cache_out[f"kv{j}"] = _prefill_kv_cache(k, v, cfg, d, max_len)
            if d.cross:
                assert enc_out is not None
                hc = rmsnorm(h, p["cross_ln"], cfg.norm_eps)
                enc_kv = enc_kv_for_cross(p["cross"], enc_out, cfg)
                h = h + cross_attn_train(p["cross"], hc, enc_kv, cfg)
                if collect_cache:
                    cache_out[f"cross{j}"] = KVCache(k=enc_kv[0], v=enc_kv[1])
        else:
            if collect_cache:
                out, mcache = mamba_lib.mamba_prefill(p["mamba"], hn, cfg)
                cache_out[f"mamba{j}"] = mcache
            else:
                out = mamba_lib.mamba_forward(p["mamba"], hn, cfg)
            h = h + out
        if d.ffn is not None:
            hn = rmsnorm(h, p["ln2"], cfg.norm_eps)
            if d.ffn == "moe":
                out, a = moe_lib.moe_apply(p["ffn"], hn, cfg)
                aux = {k_: aux[k_] + a[k_] for k_ in aux}
            else:
                out = mlp_apply(p["ffn"], hn, cfg)
            h = h + out
        h = shard_activation(h, ("batch", "seq", None))
    return h, aux, cache_out


def _prefill_kv_cache(k, v, cfg: ModelConfig, desc: LayerDesc, max_len: int) -> KVCache:
    """Arrange prefill K/V into the decode cache layout (ring for local)."""
    B, S = k.shape[:2]
    L = layer_cache_len(cfg, desc, max_len)
    if L >= max_len and S <= L:
        pad = L - S
        kc = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        vc = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        return KVCache(k=kc, v=vc)
    # ring: slot t%L holds the last position congruent to t
    W = L
    tail_positions = (S - W + jnp.arange(W)) % W if S >= W else None
    if S >= W:
        k_tail, v_tail = k[:, S - W :], v[:, S - W :]
        kc = jnp.zeros_like(k_tail).at[:, tail_positions].set(k_tail)
        vc = jnp.zeros_like(v_tail).at[:, tail_positions].set(v_tail)
        return KVCache(k=kc, v=vc)
    pad = W - S
    return KVCache(
        k=jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))),
        v=jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))),
    )


def forward_train(
    params: Params,
    tokens: jax.Array,
    cfg: ModelConfig,
    positions: Optional[jax.Array] = None,
    enc_embeds: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Full decoder forward. Returns (hidden (B,S,D), aux losses)."""
    B, S = tokens.shape
    h = embed_tokens(params["embed"], tokens, cfg)
    h = shard_activation(h, ("batch", "seq", None))
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        if cfg.mrope_sections is not None:
            positions = jnp.broadcast_to(positions[..., None], (B, S, 3))
    enc_out = None
    if cfg.enc_dec:
        assert enc_embeds is not None
        enc_out = encoder_forward(params["encoder"], enc_embeds, cfg)

    def body(carry, unit_p):
        h, aux = carry
        h, a, _ = _unit_forward(h, unit_p, positions, cfg, enc_out, False, S)
        aux = {k_: aux[k_] + a[k_] for k_ in aux}
        return (h, aux), None

    fn = jax.checkpoint(body) if cfg.remat == "full" else body
    aux0 = {
        "moe_aux": jnp.zeros((), jnp.float32),
        "moe_zloss": jnp.zeros((), jnp.float32),
    }
    (h, aux), _ = jax.lax.scan(fn, (h, aux0), params["units"])
    h = rmsnorm(h, params["final_ln"], cfg.norm_eps)
    return h, aux


def loss_fn(
    params: Params,
    batch: Dict[str, jax.Array],
    cfg: ModelConfig,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Token-mean cross-entropy with seq-chunked vocab projection."""
    tokens = batch["tokens"]
    labels = batch["labels"]
    h, aux = forward_train(
        params, tokens, cfg,
        positions=batch.get("positions"),
        enc_embeds=batch.get("enc_embeds"),
    )
    B, S, D = h.shape
    chunk = min(cfg.loss_chunk, S)
    nch = S // chunk
    h_c = h.reshape(B, nch, chunk, D).transpose(1, 0, 2, 3)
    y_c = labels.reshape(B, nch, chunk).transpose(1, 0, 2)

    def chunk_loss(carry, xs):
        hc, yc = xs
        logits = lm_logits(params["embed"], hc, cfg)        # (B,chunk,V) fp32
        logits = shard_activation(logits, ("batch", "seq", "vocab"))
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, yc[..., None], axis=-1)[..., 0]
        return carry + jnp.sum(lse - gold), None

    total, _ = jax.lax.scan(
        jax.checkpoint(chunk_loss), jnp.zeros((), jnp.float32), (h_c, y_c)
    )
    loss = total / (B * S)
    metrics = {"ce_loss": loss, **aux}
    total_loss = loss + aux["moe_aux"] + aux["moe_zloss"]
    return total_loss, metrics


# ---------------------------------------------------------------------------
# prefill / decode
# ---------------------------------------------------------------------------

def prefill(
    params: Params,
    tokens: jax.Array,
    cfg: ModelConfig,
    max_len: int,
    positions: Optional[jax.Array] = None,
    enc_embeds: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Dict]:
    """Run the prompt, build the decode cache. Returns (last-token logits,
    cache). ``max_len`` sizes the cache."""
    B, S = tokens.shape
    h = embed_tokens(params["embed"], tokens, cfg)
    h = shard_activation(h, ("batch", "seq", None))
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        if cfg.mrope_sections is not None:
            positions = jnp.broadcast_to(positions[..., None], (B, S, 3))
    enc_out = None
    if cfg.enc_dec:
        assert enc_embeds is not None
        enc_out = encoder_forward(params["encoder"], enc_embeds, cfg)

    def body(h, unit_p):
        h, _, cache_entries = _unit_forward(
            h, unit_p, positions, cfg, enc_out, True, max_len
        )
        return h, cache_entries

    h, unit_caches = jax.lax.scan(body, h, params["units"])
    h = rmsnorm(h, params["final_ln"], cfg.norm_eps)
    logits = lm_logits(params["embed"], h[:, -1:], cfg)
    cache = {"pos": jnp.full((), S, jnp.int32), "units": unit_caches}
    return logits, cache


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Dict:
    one = init_unit_cache(cfg, batch, max_len)
    U = n_units(cfg)
    units = jax.tree.map(lambda x: jnp.broadcast_to(x[None], (U,) + x.shape), one)
    return {"pos": jnp.zeros((), jnp.int32), "units": units}


def decode_step(
    params: Params,
    cache: Dict,
    token: jax.Array,                 # (B, 1) int32
    cfg: ModelConfig,
    positions: Optional[jax.Array] = None,   # (B, 1[,3]) for M-RoPE
) -> Tuple[jax.Array, Dict]:
    """One serving step: next-token logits + updated cache."""
    B = token.shape[0]
    pos = cache["pos"]
    h = embed_tokens(params["embed"], token, cfg)
    if positions is None:
        positions = jnp.broadcast_to(pos[None, None], (B, 1))
        if cfg.mrope_sections is not None:
            positions = jnp.broadcast_to(positions[..., None], (B, 1, 3))
    descs = scan_unit(cfg)

    def body(h, xs):
        unit_p, unit_c = xs
        new_c = dict(unit_c)
        for j, d in enumerate(descs):
            p = unit_p[f"L{j}"]
            hn = rmsnorm(h, p["ln"], cfg.norm_eps)
            if d.mixer == "attn":
                q, k, v = _qkv(p["attn"], hn, cfg)
                q, k = _rope_qk(q, k, positions, cfg)
                kv: KVCache = unit_c[f"kv{j}"]
                L = kv.k.shape[1]
                slot = pos % L
                kc = jax.lax.dynamic_update_slice_in_dim(kv.k, k, slot, axis=1)
                vc = jax.lax.dynamic_update_slice_in_dim(kv.v, v, slot, axis=1)
                # pin the cache layout: left unconstrained, GSPMD may flip
                # the (batch-sharded) cache to kv-head sharding mid-program
                # and gather the WHOLE cache back (measured: 2 x 86 GB/step
                # on qwen2-72b decode_32k)
                kc = shard_activation(kc, ("batch", "cache_seq", "kv_heads", None))
                vc = shard_activation(vc, ("batch", "cache_seq", "kv_heads", None))
                new_c[f"kv{j}"] = KVCache(k=kc, v=vc)
                kv_len = jnp.minimum(pos + 1, L)
                spec = AttnSpec(causal=False, window=None, softcap=cfg.attn_softcap,
                                block_q=cfg.attn_block_q, block_k=cfg.attn_block_k)
                out = flash_attention_decode(q, kc, vc, spec, q_offset=pos,
                                             kv_len=kv_len)
                h = h + jnp.einsum(
                    "bshk,hkd->bsd", out, p["attn"]["wo"].astype(out.dtype)
                )
                if d.cross:
                    hc = rmsnorm(h, p["cross_ln"], cfg.norm_eps)
                    ckv: KVCache = unit_c[f"cross{j}"]
                    cdt = hc.dtype
                    q2 = jnp.einsum("bsd,dhk->bshk", hc, p["cross"]["wq"].astype(cdt))
                    if cfg.qkv_bias:
                        q2 = q2 + p["cross"]["bq"].astype(cdt)[None, None]
                    spec2 = AttnSpec(causal=False, softcap=cfg.attn_softcap,
                                     block_q=cfg.attn_block_q, block_k=cfg.attn_block_k)
                    out2 = flash_attention_decode(q2, ckv.k, ckv.v, spec2, q_offset=0)
                    h = h + jnp.einsum(
                        "bshk,hkd->bsd", out2, p["cross"]["wo"].astype(out2.dtype)
                    )
            else:
                mc: mamba_lib.MambaCache = unit_c[f"mamba{j}"]
                out, new_mc = mamba_lib.mamba_decode_step(p["mamba"], hn, mc, cfg)
                new_c[f"mamba{j}"] = new_mc
                h = h + out
            if d.ffn is not None:
                hn = rmsnorm(h, p["ln2"], cfg.norm_eps)
                if d.ffn == "moe":
                    out, _ = moe_lib.moe_apply(p["ffn"], hn, cfg)
                else:
                    out = mlp_apply(p["ffn"], hn, cfg)
                h = h + out
        return h, new_c

    h, new_units = jax.lax.scan(body, h, (params["units"], cache["units"]))
    h = rmsnorm(h, params["final_ln"], cfg.norm_eps)
    logits = lm_logits(params["embed"], h, cfg)
    return logits, {"pos": pos + 1, "units": new_units}
