"""Model + shape configuration for the assigned architecture pool.

One :class:`ModelConfig` describes any member of the LM family used here:
dense transformer (gemma2/granite/qwen2/qwen2-vl), pure SSM (mamba2), hybrid
(jamba), MoE (qwen3-moe/kimi-k2), and encoder–decoder (whisper). The config
is pure data — the model code in :mod:`repro.models.transformer` interprets
it; the launch layer lowers it for a mesh.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff: int                 # per-expert hidden dim
    every: int = 1            # a FFN is MoE iff (layer_idx % every == every - 1)
    capacity_factor: float = 1.25
    router_z_loss: float = 1e-3
    aux_loss: float = 1e-2


@dataclass(frozen=True)
class MambaConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64        # SSD head dim (P)
    n_groups: int = 1
    chunk: int = 256          # SSD chunk length (MXU-aligned)
    dt_min: float = 0.001
    dt_max: float = 0.1

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str               # dense | ssm | hybrid | moe | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # attention flavor
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    sliding_window: Optional[int] = None    # window size for local layers
    local_global_alternate: bool = False    # gemma2: even layers local
    force_local: bool = False               # every attn layer windowed (jamba
                                            # long-context serving config)
    attn_softcap: Optional[float] = None    # gemma2: 50.0
    final_softcap: Optional[float] = None   # gemma2: 30.0
    mrope_sections: Optional[Tuple[int, int, int]] = None  # qwen2-vl M-RoPE

    # mixer pattern (hybrid / ssm)
    attn_every: Optional[int] = None        # jamba: 8 => layer i is attn iff i%8==0
    attn_free: bool = False                 # mamba2: no attention layers at all
    mamba: Optional[MambaConfig] = None

    # ffn flavor
    moe: Optional[MoEConfig] = None
    act: str = "silu"                       # silu | gelu
    gated_mlp: bool = True                  # False: 2-mat GPT-style MLP
    no_ffn: bool = False                    # mamba2: mixer-only blocks

    # encoder-decoder (whisper)
    enc_dec: bool = False
    n_enc_layers: int = 0
    enc_frames: int = 1500                  # stub frontend output length

    # embedding / head
    tie_embeddings: bool = True
    embed_scale: bool = False               # gemma2: h *= sqrt(d_model)
    norm_eps: float = 1e-6

    # numerics / execution
    param_dtype: str = "float32"            # float32 | bfloat16
    compute_dtype: str = "bfloat16"
    remat: str = "full"                     # none | full
    scan_layers: bool = True
    use_pallas: bool = False                # TPU: swap in Pallas kernels
    attn_block_q: int = 512
    attn_block_k: int = 1024
    loss_chunk: int = 1024                  # vocab-projection seq chunking

    # distribution knobs (interpreted by launch/sharding.py)
    fsdp: bool = False                      # legacy alias: parallel_mode fsdp
    parallel_mode: Optional[str] = None     # tp | fsdp | fsdp_pure | tp2d
    serve_parallel_mode: str = "tp"         # prefill/decode sharding mode
    opt_dtype: str = "float32"              # float32 | bfloat16 | int8
    micro_steps: int = 1                    # gradient-accumulation steps
    pp_stages: int = 0                      # >0: pipeline-parallel training
    pp_micro: int = 0                       # PP microbatches (0 -> 4*stages)

    @property
    def train_mode(self) -> str:
        if self.parallel_mode is not None:
            return self.parallel_mode
        return "fsdp" if self.fsdp else "tp"

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ------------------------------------------------------------- pattern
    def layer_is_attn(self, i: int) -> bool:
        if self.attn_free:
            return False
        if self.attn_every is not None:
            return i % self.attn_every == 0
        return True

    def layer_is_local(self, i: int) -> bool:
        """gemma2 alternation: even layers use the sliding window."""
        return bool(self.local_global_alternate and i % 2 == 0)

    def ffn_is_moe(self, i: int) -> bool:
        return self.moe is not None and (i % self.moe.every == self.moe.every - 1)

    # -------------------------------------------------------------- counts
    def param_count(self) -> int:
        """Exact parameter count (used for 6ND model-FLOPs roofline)."""
        D, V = self.d_model, self.vocab_size
        total = V * D  # embedding
        if not self.tie_embeddings:
            total += V * D
        total += D  # final norm
        layers = range(self.n_layers)
        for i in layers:
            total += self._block_params(i)
        if self.enc_dec:
            for i in range(self.n_enc_layers):
                total += self._enc_block_params()
            total += D  # encoder final norm
        return total

    def _attn_params(self, cross: bool = False) -> int:
        D, H, KV, hd = self.d_model, self.n_heads, self.n_kv_heads, self.head_dim
        n = D * H * hd + 2 * D * KV * hd + H * hd * D
        if self.qkv_bias:
            n += H * hd + 2 * KV * hd
        return n

    def _mlp_params(self, d_ff: int) -> int:
        return (3 if self.gated_mlp else 2) * self.d_model * d_ff

    def _moe_params(self) -> int:
        m = self.moe
        return self.d_model * m.n_experts + m.n_experts * 3 * self.d_model * m.d_ff

    def _mamba_params(self) -> int:
        mb, D = self.mamba, self.d_model
        di = mb.d_inner(D)
        hm = mb.n_heads(D)
        conv_dim = di + 2 * mb.n_groups * mb.d_state
        n = D * di * 2                      # wx, wz
        n += 2 * D * mb.n_groups * mb.d_state  # wB, wC
        n += D * hm                          # wdt
        n += mb.d_conv * conv_dim + conv_dim  # conv w + b
        n += 3 * hm                          # A_log, D_skip, dt_bias
        n += di                              # gated norm
        n += di * D                          # out_proj
        return n

    def _block_params(self, i: int) -> int:
        D = self.d_model
        n = 0
        if self.layer_is_attn(i):
            n += self._attn_params() + D  # + ln
            if self.enc_dec:
                n += self._attn_params() + D  # cross-attention + ln
        elif self.mamba is not None:
            n += self._mamba_params() + D
        if not self.no_ffn:
            if self.ffn_is_moe(i):
                n += self._moe_params() + D
            else:
                n += self._mlp_params(self.d_ff) + D
        return n

    def _enc_block_params(self) -> int:
        return self._attn_params() + self.d_model + self._mlp_params(self.d_ff) + self.d_model

    def active_param_count(self) -> int:
        """Active params per token (MoE: only top_k experts count)."""
        if self.moe is None:
            return self.param_count()
        total = self.param_count()
        m = self.moe
        n_moe_layers = sum(1 for i in range(self.n_layers) if self.ffn_is_moe(i))
        inactive_frac = (m.n_experts - m.top_k) / m.n_experts
        inactive = int(n_moe_layers * m.n_experts * 3 * self.d_model * m.d_ff * inactive_frac)
        return total - inactive


@dataclass(frozen=True)
class ShapeConfig:
    """One (input-shape) cell: what gets lowered and with which step fn."""

    name: str                 # train_4k | prefill_32k | decode_32k | long_500k
    kind: str                 # train | prefill | decode
    seq_len: int
    global_batch: int

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524_288, 1),
}
