"""Model registry: configs -> callable bundles + dry-run input specs.

``bundle(cfg)`` wraps the functional model (init / train loss / prefill /
decode) behind one object; ``input_specs(cfg, shape)`` produces the
ShapeDtypeStruct stand-ins for every model input of a cell — weak-type
correct, shardable, zero allocation — consumed by launch/dryrun.py.
"""

from __future__ import annotations

import dataclasses
import functools
from dataclasses import dataclass
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import transformer
from repro.models.config import ModelConfig, ShapeConfig
from repro.models.layers import dtype_of


@dataclass(frozen=True)
class ModelBundle:
    cfg: ModelConfig

    # ------------------------------------------------------------- factories
    def init(self, key) -> Tuple[Any, Any]:
        return transformer.init_params(key, self.cfg)

    def param_specs_tree(self):
        """(eval-shaped params, logical specs) with no allocation."""
        return param_specs(self.cfg)

    # ------------------------------------------------------------ step fns
    def loss_fn(self, params, batch):
        return transformer.loss_fn(params, batch, self.cfg)

    def prefill_fn(self, params, batch, max_len: int):
        return transformer.prefill(
            params,
            batch["tokens"],
            self.cfg,
            max_len,
            positions=batch.get("positions"),
            enc_embeds=batch.get("enc_embeds"),
        )

    def decode_fn(self, params, cache, batch):
        return transformer.decode_step(
            params, cache, batch["token"], self.cfg,
            positions=batch.get("positions"),
        )

    def init_cache(self, batch: int, max_len: int):
        return transformer.init_cache(self.cfg, batch, max_len)

    def cache_logical_specs(self):
        return {
            "pos": (),
            "units": transformer.cache_logical_specs(self.cfg),
        }


@functools.lru_cache(maxsize=64)
def param_specs(cfg: ModelConfig):
    """(param ShapeDtypeStructs, logical-axis specs) with ZERO allocation.

    The specs tree is plain Python built during tracing; we capture it as a
    side effect of ``eval_shape`` (strings can't be eval_shape outputs).
    """
    captured = {}

    def init_shapes():
        p, s = transformer.init_params(jax.random.PRNGKey(0), cfg)
        captured["specs"] = s
        return p

    shapes = jax.eval_shape(init_shapes)
    return shapes, captured["specs"]


def bundle(cfg: ModelConfig) -> ModelBundle:
    return ModelBundle(cfg)


# ---------------------------------------------------------------------------
# input specs per (cfg, shape-cell)
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for the step function's data inputs."""
    B = shape.global_batch
    S = shape.seq_len
    i32 = jnp.int32
    cdt = dtype_of(cfg.compute_dtype)
    if shape.kind == "train":
        specs = {
            "tokens": jax.ShapeDtypeStruct((B, S), i32),
            "labels": jax.ShapeDtypeStruct((B, S), i32),
        }
        if cfg.mrope_sections is not None:
            specs["positions"] = jax.ShapeDtypeStruct((B, S, 3), i32)
        if cfg.enc_dec:
            specs["enc_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.enc_frames, cfg.d_model), cdt
            )
        return specs
    if shape.kind == "prefill":
        specs = {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
        if cfg.mrope_sections is not None:
            specs["positions"] = jax.ShapeDtypeStruct((B, S, 3), i32)
        if cfg.enc_dec:
            specs["enc_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.enc_frames, cfg.d_model), cdt
            )
        return specs
    # decode: one new token against a seq_len cache
    specs = {"token": jax.ShapeDtypeStruct((B, 1), i32)}
    if cfg.mrope_sections is not None:
        specs["positions"] = jax.ShapeDtypeStruct((B, 1, 3), i32)
    return specs


def cache_specs(cfg: ModelConfig, shape: ShapeConfig) -> Any:
    """ShapeDtypeStruct tree for the decode cache of this cell."""
    return jax.eval_shape(
        lambda: transformer.init_cache(cfg, shape.global_batch, shape.seq_len)
    )
