"""Base layers: params-as-pytrees with logical sharding axes, norms,
embeddings, RoPE (+ M-RoPE), gated MLPs.

Convention: every ``init_*`` returns ``(params, specs)`` where ``specs``
mirrors the params pytree and holds a tuple of *logical axis names* per
array. The launch layer maps logical axes to mesh axes (TP/EP/FSDP) —
models never mention the mesh.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig

Params = Dict[str, Any]
Specs = Dict[str, Any]


def dtype_of(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16, "int8": jnp.int8}[name]


def truncated_normal(key, shape, stddev, dtype):
    return (stddev * jax.random.truncated_normal(key, -2.0, 2.0, shape)).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def init_rmsnorm(d: int, dtype) -> Tuple[jax.Array, Tuple]:
    return jnp.zeros((d,), dtype=dtype), ("embed",)


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    # "zero-centered" scale (gemma/llama style: weight stored as offset from 1)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dt)


# ---------------------------------------------------------------------------
# embedding
# ---------------------------------------------------------------------------

def init_embedding(key, cfg: ModelConfig) -> Tuple[Params, Specs]:
    dt = dtype_of(cfg.param_dtype)
    p = {"tok": truncated_normal(key, (cfg.vocab_size, cfg.d_model), 1.0, dt)}
    s = {"tok": ("vocab", "embed")}
    if not cfg.tie_embeddings:
        k2 = jax.random.fold_in(key, 1)
        p["head"] = truncated_normal(
            k2, (cfg.d_model, cfg.vocab_size), cfg.d_model ** -0.5, dt
        )
        s["head"] = ("embed", "vocab")
    return p, s


def embed_tokens(params: Params, tokens: jax.Array, cfg: ModelConfig) -> jax.Array:
    h = params["tok"].astype(dtype_of(cfg.compute_dtype))[tokens]
    if cfg.embed_scale:
        h = h * jnp.asarray(math.sqrt(cfg.d_model), h.dtype)
    return h


def lm_logits(params: Params, h: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Final projection; applies gemma2's final logit softcap when set."""
    if cfg.tie_embeddings:
        w = params["tok"].astype(h.dtype)  # (V, D)
        logits = jnp.einsum("...d,vd->...v", h, w, preferred_element_type=jnp.float32)
    else:
        w = params["head"].astype(h.dtype)  # (D, V)
        logits = jnp.einsum("...d,dv->...v", h, w, preferred_element_type=jnp.float32)
    if cfg.final_softcap is not None:
        c = cfg.final_softcap
        logits = c * jnp.tanh(logits / c)
    return logits


# ---------------------------------------------------------------------------
# RoPE and M-RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    exponents = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponents)  # (hd/2,)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, N, hd); positions: (B, S) int32."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                      # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, hd/2)
    sin, cos = jnp.sin(ang)[:, :, None, :], jnp.cos(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(
    x: jax.Array,
    positions3: jax.Array,
    theta: float,
    sections: Tuple[int, int, int],
) -> jax.Array:
    """Qwen2-VL multimodal RoPE: rotary dims split into (temporal, height,
    width) sections, each rotated by its own position stream.

    x: (B, S, N, hd); positions3: (B, S, 3) int32. ``sections`` counts
    frequency PAIRS per component and must sum to hd/2.
    """
    hd = x.shape[-1]
    assert sum(sections) == hd // 2, (sections, hd)
    freqs = rope_freqs(hd, theta)                      # (hd/2,)
    # pick per-frequency position component
    comp = jnp.concatenate(
        [jnp.full((s,), i, dtype=jnp.int32) for i, s in enumerate(sections)]
    )                                                  # (hd/2,)
    pos = jnp.take_along_axis(
        positions3.astype(jnp.float32),                # (B, S, 3)
        jnp.broadcast_to(comp[None, None, :], positions3.shape[:2] + comp.shape),
        axis=-1,
    )                                                  # (B, S, hd/2)
    ang = pos * freqs
    sin, cos = jnp.sin(ang)[:, :, None, :], jnp.cos(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# gated MLP (SwiGLU / GeGLU)
# ---------------------------------------------------------------------------

def init_mlp(key, cfg: ModelConfig, d_ff: int) -> Tuple[Params, Specs]:
    dt = dtype_of(cfg.param_dtype)
    D = cfg.d_model
    k1, k2, k3 = jax.random.split(key, 3)
    std_in, std_out = D ** -0.5, d_ff ** -0.5
    p = {
        "wi": truncated_normal(k1, (D, d_ff), std_in, dt),
        "wo": truncated_normal(k3, (d_ff, D), std_out, dt),
    }
    s = {"wi": ("embed", "mlp"), "wo": ("mlp", "embed")}
    if cfg.gated_mlp:
        p["wg"] = truncated_normal(k2, (D, d_ff), std_in, dt)
        s["wg"] = ("embed", "mlp")
    return p, s


def activation(x: jax.Array, act: str) -> jax.Array:
    if act == "silu":
        return jax.nn.silu(x)
    if act == "gelu":
        return jax.nn.gelu(x, approximate=True)
    raise ValueError(act)


def mlp_apply(params: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    dt = x.dtype
    up = jnp.einsum("...d,df->...f", x, params["wi"].astype(dt))
    if cfg.gated_mlp:
        gate = activation(
            jnp.einsum("...d,df->...f", x, params["wg"].astype(dt)), cfg.act
        )
        h = gate * up
    else:
        h = activation(up, cfg.act)
    return jnp.einsum("...f,fd->...d", h, params["wo"].astype(dt))


# ---------------------------------------------------------------------------
# spec utilities
# ---------------------------------------------------------------------------

def stack_specs(specs: Specs) -> Specs:
    """Prepend the scanned 'layers' axis to every leaf spec."""
    return jax.tree.map(
        lambda s: ("layers",) + tuple(s),
        specs,
        is_leaf=lambda s: isinstance(s, tuple) and all(isinstance(x, (str, type(None))) for x in s),
    )


def stack_params(key, n: int, init_one) -> Tuple[Params, Specs]:
    """Initialize n layers and stack each leaf along axis 0 (scan layout)."""
    ps, specs = [], None
    for i in range(n):
        p, s = init_one(jax.random.fold_in(key, i))
        ps.append(p)
        specs = s
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *ps)
    return stacked, stack_specs(specs)
