"""Blocked (flash-style) attention in pure JAX — the XLA path of the model
substrate, shared by every attention-bearing assigned architecture.

Design (DESIGN.md §7): O(S) memory online-softmax attention with

- GQA (grouped einsums — KV heads are never materialized H times),
- causal masking with STATIC block skipping (the strictly-upper-triangle
  blocks are never computed, so ``cost_analysis`` FLOPs reflect the real
  work — no masked-but-counted waste),
- sliding-window (gemma2 local layers; jamba long-context) with static
  block-range restriction,
- attention logit softcapping (gemma2),
- a manual flash backward (``custom_vjp``): forward saves only (out, lse);
  backward recomputes probabilities blockwise from the saved lse.

The Pallas TPU kernel (:mod:`repro.kernels.flash_attention`) implements the
same spec; ``naive_attention`` here is the semantic oracle for both.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AttnSpec:
    causal: bool = True
    window: Optional[int] = None      # sliding-window size (None = unbounded)
    softcap: Optional[float] = None   # attention-logit softcap (gemma2: 50.0)
    block_q: int = 512
    block_k: int = 1024

    def scale(self, head_dim: int) -> float:
        return head_dim ** -0.5


NEG_INF = -1e30


def _softcap(scores: jax.Array, cap: Optional[float]) -> jax.Array:
    if cap is None:
        return scores
    return cap * jnp.tanh(scores / cap)


# ---------------------------------------------------------------------------
# naive oracle
# ---------------------------------------------------------------------------

def naive_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    spec: AttnSpec,
    q_offset=0,
    kv_len: Optional[jax.Array] = None,
) -> jax.Array:
    """Materialized-scores reference. q: (B,Sq,H,hd); k,v: (B,Skv,KV,hd)."""
    B, Sq, H, hd = q.shape
    _, Skv, KV, _ = k.shape
    G = H // KV
    q5 = q.reshape(B, Sq, KV, G, hd)
    scores = jnp.einsum(
        "bqkgh,bskh->bkgqs", q5, k, preferred_element_type=jnp.float32
    ) * spec.scale(hd)
    scores = _softcap(scores, spec.softcap)
    qpos = q_offset + jnp.arange(Sq)[:, None]          # (Sq, 1)
    kpos = jnp.arange(Skv)[None, :]                     # (1, Skv)
    mask = jnp.ones((Sq, Skv), dtype=bool)
    if spec.causal:
        mask &= kpos <= qpos
    if spec.window is not None:
        mask &= kpos > qpos - spec.window
    if kv_len is not None:
        mask &= kpos < kv_len
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskh->bqkgh", p.astype(v.dtype), v)
    return out.reshape(B, Sq, H, hd)


# ---------------------------------------------------------------------------
# static block-range logic
# ---------------------------------------------------------------------------

def _kv_block_range(
    qi: int, spec: AttnSpec, Sq: int, Skv: int, nk_total: int
) -> Tuple[int, int]:
    """[lo_blk, hi_blk) of kv blocks q block ``qi`` touches (train/prefill
    path: q_offset == 0 and Sq == Skv when causal)."""
    bq, bk = spec.block_q, spec.block_k
    q_lo, q_hi = qi * bq, min((qi + 1) * bq, Sq) - 1
    lo, hi = 0, Skv
    if spec.causal:
        hi = min(hi, q_hi + 1)
    if spec.window is not None:
        lo = max(lo, q_lo - spec.window + 1)
    lo_blk = lo // bk
    hi_blk = -(-hi // bk)  # ceil
    return lo_blk, min(hi_blk, nk_total)


def _block_mask(
    q_pos: jax.Array, k_pos: jax.Array, spec: AttnSpec, kv_len
) -> jax.Array:
    """(bq, bk) bool mask for one (q block, kv block) pair."""
    qp, kp = q_pos[:, None], k_pos[None, :]
    mask = jnp.ones(qp.shape[:1] + kp.shape[1:], dtype=bool)
    if spec.causal:
        mask &= kp <= qp
    if spec.window is not None:
        mask &= kp > qp - spec.window
    if kv_len is not None:
        mask &= kp < kv_len
    return mask


# ---------------------------------------------------------------------------
# forward core: one q block, scanning its kv range
# ---------------------------------------------------------------------------

def _fwd_one_q_block(
    q_blk: jax.Array,      # (B, KV, G, bq, hd)
    k_sub: jax.Array,      # (B, kv_span, KV, hd)
    v_sub: jax.Array,
    q_pos: jax.Array,      # (bq,) absolute positions
    k_pos0: int | jax.Array,
    spec: AttnSpec,
    kv_len,
    needs_mask: bool,
) -> Tuple[jax.Array, jax.Array]:
    """Online-softmax over kv blocks. Returns (out_blk (B,KV,G,bq,hd), lse)."""
    B, KV, G, bq, hd = q_blk.shape
    span = k_sub.shape[1]
    bk = spec.block_k
    nk = span // bk
    scale = spec.scale(hd)

    def body(carry, i):
        m, l, acc = carry
        k_blk = jax.lax.dynamic_slice_in_dim(k_sub, i * bk, bk, axis=1)
        v_blk = jax.lax.dynamic_slice_in_dim(v_sub, i * bk, bk, axis=1)
        s = jnp.einsum(
            "bkgqh,btkh->bkgqt", q_blk, k_blk, preferred_element_type=jnp.float32
        ) * scale
        s = _softcap(s, spec.softcap)
        if needs_mask or kv_len is not None:
            k_pos = k_pos0 + i * bk + jnp.arange(bk)
            mask = _block_mask(q_pos, k_pos, spec, kv_len)
            s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * alpha + p.sum(axis=-1)
        pv = jnp.einsum(
            "bkgqt,btkh->bkgqh", p.astype(v_blk.dtype), v_blk,
            preferred_element_type=jnp.float32,
        )
        acc_new = acc * alpha[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, KV, G, bq), NEG_INF, dtype=jnp.float32)
    l0 = jnp.zeros((B, KV, G, bq), dtype=jnp.float32)
    acc0 = jnp.zeros((B, KV, G, bq, hd), dtype=jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, acc0), jnp.arange(nk))
    l_safe = jnp.maximum(l, 1e-30)
    out = (acc / l_safe[..., None]).astype(q_blk.dtype)
    lse = m + jnp.log(l_safe)
    return out, lse


def _flash_forward(q, k, v, spec: AttnSpec, kv_len=None):
    """Unrolled loop over q blocks; each q block scans only the kv blocks its
    (causal, window) range statically requires."""
    B, Sq, H, hd = q.shape
    _, Skv, KV, _ = k.shape
    G = H // KV
    bq, bk = spec.block_q, spec.block_k
    nq, nk = Sq // bq, Skv // bk
    q5 = q.reshape(B, Sq, KV, G, hd).transpose(0, 2, 3, 1, 4)  # (B,KV,G,Sq,hd)

    outs, lses = [], []
    for qi in range(nq):
        lo_blk, hi_blk = _kv_block_range(qi, spec, Sq, Skv, nk)
        q_blk = jax.lax.slice_in_dim(q5, qi * bq, (qi + 1) * bq, axis=3)
        k_sub = jax.lax.slice_in_dim(k, lo_blk * bk, hi_blk * bk, axis=1)
        v_sub = jax.lax.slice_in_dim(v, lo_blk * bk, hi_blk * bk, axis=1)
        q_pos = qi * bq + jnp.arange(bq)
        # masking needed only when the block range boundary cuts a block
        needs_mask = spec.causal or spec.window is not None
        out_blk, lse_blk = _fwd_one_q_block(
            q_blk, k_sub, v_sub, q_pos, lo_blk * bk, spec, kv_len, needs_mask
        )
        outs.append(out_blk)
        lses.append(lse_blk)
    out = jnp.concatenate(outs, axis=3)   # (B,KV,G,Sq,hd)
    lse = jnp.concatenate(lses, axis=3)   # (B,KV,G,Sq)
    out_b = out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, hd)
    return out_b, (out, lse)


# ---------------------------------------------------------------------------
# manual flash backward
# ---------------------------------------------------------------------------

def _flash_backward(q, k, v, out5, lse, g, spec: AttnSpec):
    """Recompute-probabilities backward.

    q: (B,Sq,H,hd) primal; out5/lse: (B,KV,G,Sq,·) saved; g: (B,Sq,H,hd).
    Returns (dq, dk, dv) with the same static block structure as forward.
    """
    B, Sq, H, hd = q.shape
    _, Skv, KV, _ = k.shape
    G = H // KV
    bq, bk = spec.block_q, spec.block_k
    nq, nk = Sq // bq, Skv // bk
    scale = spec.scale(hd)

    q5 = q.reshape(B, Sq, KV, G, hd).transpose(0, 2, 3, 1, 4)
    g5 = g.reshape(B, Sq, KV, G, hd).transpose(0, 2, 3, 1, 4)
    # D_i = sum_h dOut_i * Out_i  (per row)
    delta = jnp.sum(g5.astype(jnp.float32) * out5.astype(jnp.float32), axis=-1)

    dq5 = jnp.zeros_like(q5, dtype=jnp.float32)
    dk = jnp.zeros_like(k, dtype=jnp.float32)
    dv = jnp.zeros_like(v, dtype=jnp.float32)

    for qi in range(nq):
        lo_blk, hi_blk = _kv_block_range(qi, spec, Sq, Skv, nk)
        span = (hi_blk - lo_blk) * bk
        q_blk = jax.lax.slice_in_dim(q5, qi * bq, (qi + 1) * bq, axis=3)
        g_blk = jax.lax.slice_in_dim(g5, qi * bq, (qi + 1) * bq, axis=3)
        lse_blk = jax.lax.slice_in_dim(lse, qi * bq, (qi + 1) * bq, axis=3)
        dlt_blk = jax.lax.slice_in_dim(delta, qi * bq, (qi + 1) * bq, axis=3)
        k_sub = jax.lax.slice_in_dim(k, lo_blk * bk, hi_blk * bk, axis=1)
        v_sub = jax.lax.slice_in_dim(v, lo_blk * bk, hi_blk * bk, axis=1)
        q_pos = qi * bq + jnp.arange(bq)

        def body(carry, i):
            dq_acc, dk_sub, dv_sub = carry
            k_blk = jax.lax.dynamic_slice_in_dim(k_sub, i * bk, bk, axis=1)
            v_blk = jax.lax.dynamic_slice_in_dim(v_sub, i * bk, bk, axis=1)
            s_raw = jnp.einsum(
                "bkgqh,btkh->bkgqt", q_blk, k_blk,
                preferred_element_type=jnp.float32,
            ) * scale
            if spec.softcap is not None:
                t = jnp.tanh(s_raw / spec.softcap)
                s = spec.softcap * t
                dcap = 1.0 - t * t      # d softcap(s)/ds
            else:
                s = s_raw
                dcap = None
            k_pos = lo_blk * bk + i * bk + jnp.arange(bk)
            mask = _block_mask(q_pos, k_pos, spec, None)
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            p = jnp.exp(s - lse_blk[..., None])          # (B,KV,G,bq,bk)
            dp = jnp.einsum(
                "bkgqh,btkh->bkgqt", g_blk.astype(jnp.float32),
                v_blk.astype(jnp.float32), preferred_element_type=jnp.float32,
            )
            ds = p * (dp - dlt_blk[..., None])           # d wrt softcapped s
            if dcap is not None:
                ds = ds * dcap
            ds = ds * scale
            dq_acc = dq_acc + jnp.einsum(
                "bkgqt,btkh->bkgqh", ds, k_blk.astype(jnp.float32),
                preferred_element_type=jnp.float32,
            )
            dk_blk = jnp.einsum(
                "bkgqt,bkgqh->btkh", ds, q_blk.astype(jnp.float32),
                preferred_element_type=jnp.float32,
            )
            dv_blk = jnp.einsum(
                "bkgqt,bkgqh->btkh", p, g_blk.astype(jnp.float32),
                preferred_element_type=jnp.float32,
            )
            dk_sub = jax.lax.dynamic_update_slice_in_dim(
                dk_sub, jax.lax.dynamic_slice_in_dim(dk_sub, i * bk, bk, 1) + dk_blk,
                i * bk, axis=1,
            )
            dv_sub = jax.lax.dynamic_update_slice_in_dim(
                dv_sub, jax.lax.dynamic_slice_in_dim(dv_sub, i * bk, bk, 1) + dv_blk,
                i * bk, axis=1,
            )
            return (dq_acc, dk_sub, dv_sub), None

        nk_q = span // bk
        dq0 = jnp.zeros_like(q_blk, dtype=jnp.float32)
        dk_sub0 = jnp.zeros((B, span, KV, hd), dtype=jnp.float32)
        dv_sub0 = jnp.zeros((B, span, KV, hd), dtype=jnp.float32)
        (dq_blk, dk_sub, dv_sub), _ = jax.lax.scan(
            body, (dq0, dk_sub0, dv_sub0), jnp.arange(nk_q)
        )
        dq5 = jax.lax.dynamic_update_slice_in_dim(dq5, dq_blk, qi * bq, axis=3)
        dk = jax.lax.dynamic_update_slice_in_dim(
            dk, jax.lax.dynamic_slice_in_dim(dk, lo_blk * bk, span, 1) + dk_sub,
            lo_blk * bk, axis=1,
        )
        dv = jax.lax.dynamic_update_slice_in_dim(
            dv, jax.lax.dynamic_slice_in_dim(dv, lo_blk * bk, span, 1) + dv_sub,
            lo_blk * bk, axis=1,
        )

    dq = dq5.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, hd).astype(q.dtype)
    return dq, dk.astype(k.dtype), dv.astype(v.dtype)


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------

def _divisible(q, k, spec: AttnSpec) -> bool:
    return (
        q.shape[1] % spec.block_q == 0
        and k.shape[1] % spec.block_k == 0
        and q.shape[1] >= spec.block_q
        and k.shape[1] >= spec.block_k
    )


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def flash_attention_train(q, k, v, spec: AttnSpec):
    """Training attention (q_offset=0). Falls back to the naive oracle for
    shapes that don't tile (tiny smoke configs)."""
    if not _divisible(q, k, spec):
        return naive_attention(q, k, v, spec)
    out, _ = _flash_forward(q, k, v, spec)
    return out


def _fa_fwd(q, k, v, spec: AttnSpec):
    if not _divisible(q, k, spec):
        # fall back to AD through the naive path
        out, vjp = jax.vjp(lambda q, k, v: naive_attention(q, k, v, spec), q, k, v)
        return out, (None, vjp)
    out, (out5, lse) = _flash_forward(q, k, v, spec)
    return out, ((q, k, v, out5, lse), None)


def _fa_bwd(spec: AttnSpec, res, g):
    saved, naive_vjp = res
    if saved is None:
        return naive_vjp(g)
    q, k, v, out5, lse = saved
    return _flash_backward(q, k, v, out5, lse, g, spec)


flash_attention_train.defvjp(_fa_fwd, _fa_bwd)


def flash_attention_decode(q, k, v, spec: AttnSpec, q_offset, kv_len=None):
    """Decode attention against a (possibly padded) KV cache. Sq is tiny
    (usually 1); ``q_offset`` may be a traced scalar (decode position), so
    the kv block range cannot be statically narrowed — every cache block is
    computed and masked by ``kv_len``, the honest worst case for a serving
    step at full context. Prefill should use ``flash_attention_train``
    (q_offset = 0 ⇒ identical semantics, static block skipping)."""
    B, Sq, H, hd = q.shape
    _, Skv, KV, _ = k.shape
    G = H // KV
    bk = spec.block_k if Skv % spec.block_k == 0 else Skv
    q5 = q.reshape(B, Sq, KV, G, hd).transpose(0, 2, 3, 1, 4)
    q_pos = q_offset + jnp.arange(Sq)
    out_blk, _ = _fwd_one_q_block(
        q5,
        k,
        v,
        q_pos,
        0,
        AttnSpec(
            causal=spec.causal,
            window=spec.window,
            softcap=spec.softcap,
            block_q=Sq,
            block_k=bk,
        ),
        kv_len,
        needs_mask=True,
    )
    return out_blk.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, hd)
