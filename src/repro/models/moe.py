"""Mixture-of-Experts FFN with sort-based capacity dispatch (GShard-style
groups, Switch-style capacity), expert-parallel over the mesh ``model`` axis.

Memory-lean dispatch: instead of the (T, E, C) one-hot dispatch tensor we
``argsort`` token->expert assignments and build an (E*C,) gather table of
token indices — O(T·K) integer work, no giant boolean masks. Tokens beyond
an expert's capacity are dropped (their combine weight is zero), standard
for capacity-factor routing.

Grouping: tokens are routed within groups (= batch rows), so the gather
stays local to the data shard; the (G, E, C, D) dispatched tensor is then
resharded expert->model, which lowers to the canonical MoE all-to-all.
"""

from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import activation, dtype_of, truncated_normal
from repro.launch.sharding import shard_activation


def init_moe(key, cfg: ModelConfig) -> Tuple[Dict, Dict]:
    m = cfg.moe
    D, E, F = cfg.d_model, m.n_experts, m.d_ff
    dt = dtype_of(cfg.param_dtype)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    std_in, std_out = D ** -0.5, F ** -0.5
    p = {
        "router": truncated_normal(k1, (D, E), std_in, jnp.float32),
        "wi": truncated_normal(k2, (E, D, F), std_in, dt),
        "wg": truncated_normal(k3, (E, D, F), std_in, dt),
        "wo": truncated_normal(k4, (E, F, D), std_out, dt),
    }
    s = {
        "router": ("embed", None),
        "wi": ("experts", "embed", "expert_mlp"),
        "wg": ("experts", "embed", "expert_mlp"),
        "wo": ("experts", "expert_mlp", "embed"),
    }
    return p, s


def capacity(tokens_per_group: int, cfg: ModelConfig) -> int:
    m = cfg.moe
    c = math.ceil(tokens_per_group * m.top_k * m.capacity_factor / m.n_experts)
    # pad to 8 for clean MXU tiling only when the capacity is already large;
    # decode groups (1 token) must NOT inflate E*C slots 8x (useful-flops!)
    if c >= 8:
        return 8 * math.ceil(c / 8)
    return max(c, 1)


def moe_apply(
    p: Dict, x: jax.Array, cfg: ModelConfig
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """x: (B, S, D) -> (out (B, S, D), aux losses).

    Groups = batch rows (B); routing, capacity, and the gather/scatter are
    all per-group (local to the data shard).
    """
    m = cfg.moe
    B, S, D = x.shape
    orig_shape = None
    if S == 1 and B > 1:
        # decode regrouping: per-row groups would allocate E*C slots PER ROW
        # (128x wasted expert FLOPs at B=128, E=128); one global group keeps
        # slots ~= tokens * top_k * cf. The token gather crosses data shards
        # but moves only (B, D) bytes — negligible at decode.
        orig_shape = (B, S, D)
        x = x.reshape(1, B, D)
        B, S = 1, B
    E, K = m.n_experts, m.top_k
    C = capacity(S, cfg)
    cdt = x.dtype

    # ---- routing (fp32)
    logits = jnp.einsum(
        "gsd,de->gse", x.astype(jnp.float32), p["router"]
    )                                                   # (B,S,E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, K)              # (B,S,K)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    # ---- aux losses (Switch/GShard load balance + router z-loss)
    me = probs.mean(axis=(0, 1))                        # (E,)
    ce = jnp.zeros((E,), jnp.float32).at[top_e.reshape(-1)].add(
        jnp.ones((B * S * K,), jnp.float32)
    ) / (B * S * K)
    aux = E * jnp.sum(me * ce) * m.aux_loss
    zl = jnp.mean(jax.scipy.special.logsumexp(logits, axis=-1) ** 2) * m.router_z_loss

    # ---- sort-based dispatch, per group
    TK = S * K
    expert_flat = top_e.reshape(B, TK)                  # (B, TK)
    w_flat = top_w.reshape(B, TK)
    token_idx = jnp.broadcast_to(
        jnp.arange(S)[:, None], (S, K)
    ).reshape(TK)                                       # (TK,)
    order = jnp.argsort(expert_flat, axis=-1, stable=True)
    sorted_e = jnp.take_along_axis(expert_flat, order, axis=-1)
    sorted_t = token_idx[order]                         # (B, TK)
    sorted_w = jnp.take_along_axis(w_flat, order, axis=-1)
    counts = jax.nn.one_hot(sorted_e, E, dtype=jnp.int32).sum(axis=1)  # (B,E)
    offsets = jnp.cumsum(counts, axis=-1) - counts      # (B,E) exclusive
    rank = jnp.arange(TK)[None, :] - jnp.take_along_axis(offsets, sorted_e, -1)
    keep = rank < C
    slot = jnp.where(keep, sorted_e * C + rank, E * C)  # overflow -> sentinel

    # gather table (B, E*C+1): token index per expert slot, sentinel = S
    table = jnp.full((B, E * C + 1), S, dtype=jnp.int32)
    table = jax.vmap(lambda t, s, tok: t.at[s].set(tok))(table, slot, sorted_t)
    table = table[:, : E * C]
    wtab = jnp.zeros((B, E * C + 1), dtype=jnp.float32)
    wtab = jax.vmap(lambda t, s, w: t.at[s].set(w))(wtab, slot, sorted_w)
    wtab = wtab[:, : E * C]

    # ---- dispatch: (B, E, C, D), expert-sharded
    x_pad = jnp.concatenate([x, jnp.zeros((B, 1, D), cdt)], axis=1)  # sentinel row
    xg = jnp.take_along_axis(
        x_pad, table[:, :, None], axis=1
    ).reshape(B, E, C, D)
    xg = shard_activation(xg, ("batch", "experts", None, None))

    # ---- expert FFN (E-parallel einsums). The hidden constraint makes the
    # tp2d mode explicit: with expert_mlp -> data, h stays F-sharded, the
    # expert weights stay stationary, and the down-proj contraction lowers
    # to an activation psum (no weight all-gathers). Under tp/fsdp modes the
    # constraint maps to replicated-F: a no-op.
    gate = activation(
        jnp.einsum("becd,edf->becf", xg, p["wg"].astype(cdt)), cfg.act
    )
    up = jnp.einsum("becd,edf->becf", xg, p["wi"].astype(cdt))
    h = shard_activation(gate * up, ("batch", "experts", None, "expert_mlp"))
    y = jnp.einsum("becf,efd->becd", h, p["wo"].astype(cdt))
    y = shard_activation(y, ("batch", "experts", None, None))

    # ---- combine: weighted scatter-add back to token order
    y_flat = y.reshape(B, E * C, D) * wtab[:, :, None].astype(cdt)
    out = jnp.zeros((B, S + 1, D), cdt)
    out = jax.vmap(lambda o, t, v: o.at[t].add(v))(out, table, y_flat)
    out = out[:, :S]
    if orig_shape is not None:
        out = out.reshape(orig_shape)
    out = shard_activation(out, ("batch", None, None))
    return out, {"moe_aux": aux, "moe_zloss": zl}
